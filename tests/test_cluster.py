"""Cluster tier tests (DESIGN §14).

Covers the partition directory (consistent-hash minimal movement, range
placement, epoch versioning, durable publish/recover), the multi-node
store (sharded persist, reopen bit-identity, replica-fallback reads
after losing a node's files), the incremental rebalancer (minimal move
set, the bytes-moved bound vs a naive full re-shuffle, crash-before-
epoch-commit recovery, stale-plan rejection), MVCC reads racing the
rebalance pointer flip (deterministic sync points), straggler reissue on
the part-read path, and the Autopilot loop: a lost/slow node's health
signal priced into a rebalance decision recorded in ``decisions.log``.
"""

import os
import shutil
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.api import Session
from repro.cluster import (CONSISTENT_HASH, RANGE_PLACEMENT, ClusterConfig,
                           ClusterHealth, PartitionDirectory,
                           RebalanceAborted, Rebalancer)
from repro.cluster.directory import EPOCH_POINTER
from repro.data.partition_store import PartitionStore
from repro.service import (Autopilot, AutopilotConfig, LogicalClock,
                           drift_tables, q_orderkey)

M = 8
NODES = ("alpha", "beta")


def _data(rows=400, cols=3, seed=0):
    rng = np.random.default_rng(seed)
    return {f"c{i}": rng.standard_normal(rows).astype(np.float64)
            for i in range(cols)}


def _canonical(ds):
    return {k: np.asarray(v).copy() for k, v in sorted(ds.gather().items())}


def _assert_same(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def _cluster_store(root, nodes=NODES, replication=2, num_workers=M, **kw):
    return PartitionStore(
        root=root, num_workers=num_workers,
        cluster=ClusterConfig(nodes=nodes, replication=replication, **kw))


# ---------------------------------------------------------------------------
# PartitionDirectory
# ---------------------------------------------------------------------------

def test_directory_build_is_deterministic_and_replicated():
    a = PartitionDirectory.build(16, ("n0", "n1", "n2"), replication=2)
    b = PartitionDirectory.build(16, ("n0", "n1", "n2"), replication=2)
    assert a.to_json() == b.to_json()
    for p in range(16):
        reps = a.replicas_of(p)
        assert len(reps) == 2 and len(set(reps)) == 2
        assert a.node_of(p) == reps[0]
        assert all(r in ("n0", "n1", "n2") for r in reps)


def test_directory_replication_caps_at_node_count():
    d = PartitionDirectory.build(8, ("solo",), replication=3)
    assert all(d.replicas_of(p) == ("solo",) for p in range(8))


def test_consistent_hash_moves_minimally_on_node_add():
    old = PartitionDirectory.build(64, ("n0", "n1", "n2", "n3"),
                                   replication=1)
    new = old.with_nodes(("n0", "n1", "n2", "n3", "n4"))
    moved = old.diff(new)
    # ideal is m/n = 12.8; the 64-virtual-point ring stays well under a
    # full reshuffle and every move lands on the new node
    assert 0 < len(moved) < 32
    assert all(dst == "n4" for _, _, dst in moved)
    # unmoved partitions keep their primary byte-for-byte
    movedset = {p for p, _, _ in moved}
    for p in range(64):
        if p not in movedset:
            assert old.node_of(p) == new.node_of(p)


def test_range_placement_is_contiguous():
    d = PartitionDirectory.build(8, ("n0", "n1"), strategy=RANGE_PLACEMENT,
                                 replication=1)
    assert [d.node_of(p) for p in range(8)] == ["n0"] * 4 + ["n1"] * 4
    assert d.strategy == RANGE_PLACEMENT


def test_directory_epoch_bumps_and_diff_guards():
    d = PartitionDirectory.build(8, NODES)
    assert d.epoch == 0
    d2 = d.with_nodes(("alpha", "beta", "gamma"))
    assert d2.epoch == 1
    with pytest.raises(ValueError):
        d.diff(d.with_m(16))          # m mismatch is not diffable


def test_directory_publish_and_load_current(tmp_path):
    root = str(tmp_path)
    d = PartitionDirectory.build(8, NODES, replication=2)
    d.publish(root)
    d2 = d.with_nodes(("alpha",))
    d2.publish(root)
    got = PartitionDirectory.load_current(root)
    assert got.epoch == 1 and got.nodes == ("alpha",)
    # a torn EPOCH pointer falls back to the newest parseable directory
    with open(os.path.join(root, EPOCH_POINTER), "w") as f:
        f.write("garbage")
    got = PartitionDirectory.load_current(root)
    assert got.epoch == 1 and got.nodes == ("alpha",)


# ---------------------------------------------------------------------------
# Multi-node store: persist, reopen, replica fallback
# ---------------------------------------------------------------------------

def test_cluster_store_reopen_bit_identical(tmp_path):
    root = str(tmp_path / "store")
    store = _cluster_store(root)
    store.write("d", _data())
    before = _canonical(store.read("d"))
    assert store.is_cluster and store.placement_epoch == 0
    # segments land under per-node roots, not the flat dataset dir
    for node in NODES:
        assert os.path.isdir(os.path.join(root, "nodes", node))
    del store

    re = PartitionStore(root=root, num_workers=M)   # cluster.json redetects
    assert re.is_cluster and re.directory.nodes == NODES
    _assert_same(_canonical(re.read("d")), before)


def test_cluster_store_serves_from_replicas_after_node_loss(tmp_path):
    root = str(tmp_path / "store")
    store = _cluster_store(root, replication=2)
    store.write("d", _data(seed=1))
    before = _canonical(store.read("d"))
    del store
    shutil.rmtree(os.path.join(root, "nodes", "beta"))

    re = PartitionStore(root=root, num_workers=M)
    _assert_same(_canonical(re.read("d")), before)


def test_cluster_store_rejects_memory_budget(tmp_path):
    with pytest.raises(ValueError, match="memory_budget_bytes"):
        PartitionStore(root=str(tmp_path / "s"), num_workers=M,
                       cluster=ClusterConfig(nodes=NODES),
                       memory_budget_bytes=1 << 20)


# ---------------------------------------------------------------------------
# Incremental rebalancing
# ---------------------------------------------------------------------------

def test_rebalance_moves_only_changed_partitions(tmp_path):
    root = str(tmp_path / "store")
    store = _cluster_store(root, nodes=("n0", "n1", "n2", "n3"),
                           replication=1, num_workers=32)
    store.write("d", _data(rows=3200, seed=2))
    before = _canonical(store.read("d"))
    total = float(store.read("d").padded_bytes)

    plan = store.plan_rebalance(add_nodes=("n4",), reason="scale-out")
    assert 0 < plan.partitions_moved < 32
    res = store.rebalance(plan=plan)
    assert res.epoch == 1 and store.placement_epoch == 1

    # the acceptance bound: incremental ≤ (moved/m) × total, and strictly
    # under the naive full re-shuffle (= every padded byte rewritten)
    assert res.bytes_moved <= plan.partitions_moved / 32 * total + 1e-9
    assert res.bytes_moved < total
    assert res.partitions_moved == plan.partitions_moved
    _assert_same(_canonical(store.read("d")), before)

    # fresh process sees the committed epoch and the same bits
    del store
    re = PartitionStore(root=root, num_workers=32)
    assert re.placement_epoch == 1 and "n4" in re.directory.nodes
    _assert_same(_canonical(re.read("d")), before)


def test_rebalance_node_remove_serves_all_partitions(tmp_path):
    root = str(tmp_path / "store")
    store = _cluster_store(root, nodes=("alpha", "beta", "gamma"),
                           replication=2)
    store.write("d", _data(seed=3))
    before = _canonical(store.read("d"))
    res = store.rebalance(remove_nodes=("beta",), reason="drain")
    assert res.epoch == 1
    assert store.directory.nodes == ("alpha", "gamma")
    del store
    shutil.rmtree(os.path.join(root, "nodes", "beta"))
    re = PartitionStore(root=root, num_workers=M)
    _assert_same(_canonical(re.read("d")), before)


def test_rebalance_stale_plan_rejected(tmp_path):
    store = _cluster_store(str(tmp_path / "s"))
    store.write("d", _data())
    stale = store.plan_rebalance(add_nodes=("gamma",))
    store.rebalance(add_nodes=("delta",))
    with pytest.raises(ValueError, match="stale"):
        store.rebalance(plan=stale)


def test_rebalance_noop_membership_rejected(tmp_path):
    store = _cluster_store(str(tmp_path / "s"))
    with pytest.raises(ValueError):
        store.plan_rebalance(nodes=NODES)          # unchanged
    with pytest.raises(ValueError):
        store.plan_rebalance(remove_nodes=NODES)   # empty cluster


def test_rebalance_crash_before_epoch_commit_recovers(tmp_path):
    root = str(tmp_path / "store")
    store = _cluster_store(root)
    store.write("d", _data(seed=4))
    store.write("e", _data(seed=5))
    before = {n: _canonical(store.read(n)) for n in ("d", "e")}

    plan = store.plan_rebalance(add_nodes=("gamma",), reason="crash-test")
    with pytest.raises(RebalanceAborted):
        store.rebalance(plan=plan, abort_after=1)
    del store
    # half-streamed segments may exist, but the EPOCH pointer never
    # flipped: a fresh process recovers the old placement bit-identically
    shutil.rmtree(os.path.join(root, "nodes", "gamma"), ignore_errors=True)
    re = PartitionStore(root=root, num_workers=M)
    assert re.placement_epoch == 0
    assert re.directory.nodes == NODES
    for n in ("d", "e"):
        _assert_same(_canonical(re.read(n)), before[n])


# ---------------------------------------------------------------------------
# MVCC: concurrent readers across the rebalance flip (sync-point race)
# ---------------------------------------------------------------------------

class _Freeze:
    def __init__(self):
        self.reached = threading.Event()
        self._go = threading.Event()
        self._armed = True

    def __call__(self):
        if not self._armed:
            return
        self._armed = False
        self.reached.set()
        assert self._go.wait(60), "race test deadlocked at sync point"

    def release(self):
        self._go.set()


def test_reader_pinned_across_rebalance_flip(tmp_path):
    store = _cluster_store(str(tmp_path / "s"))
    store.write("d", _data(seed=6))
    baseline = _canonical(store.read("d"))
    pinned = store.read("d")
    gen0 = pinned.generation

    freeze = _Freeze()
    store.set_sync_point("install:pre_flip", freeze)
    err = []

    def _rebalance():
        try:
            store.rebalance(add_nodes=("gamma",))
        except BaseException as e:    # noqa: BLE001 — surfaced below
            err.append(e)

    t = threading.Thread(target=_rebalance)
    try:
        t.start()
        assert freeze.reached.wait(60)
        # the rebalancer is parked one instruction before the pointer
        # flip: a read right now resolves the old generation, bit-identical
        racer = store.read("d")
        assert racer.generation == gen0
        _assert_same(_canonical(racer), baseline)
        freeze.release()
        t.join(60)
        assert not err, err
    finally:
        store.set_sync_point("install:pre_flip", None)

    # flip landed: new generation, same bits; the pinned reader still
    # serves its own generation unchanged (MVCC)
    assert store.read("d").generation > gen0
    _assert_same(_canonical(store.read("d")), baseline)
    assert pinned.generation == gen0
    _assert_same(_canonical(pinned), baseline)
    assert store.placement_epoch == 1


# ---------------------------------------------------------------------------
# Straggler reissue on the part-read path
# ---------------------------------------------------------------------------

def test_slow_node_reads_reissue_to_replicas(tmp_path):
    root = str(tmp_path / "store")
    store = _cluster_store(root, nodes=("alpha", "beta", "gamma"),
                           replication=3)
    store.write("d", _data(seed=7))
    before = _canonical(store.read("d"))
    del store

    re = PartitionStore(root=root, num_workers=M)
    _assert_same(_canonical(re.read("d")), before)
    man = re.durable.load_manifest("d")
    want = re.durable.open_columns("d", man)   # clean reference assembly

    health = re.health
    health.set_read_latency(
        lambda node: 1.0 if node == "beta" else 0.001)
    sigs = []
    for _ in range(4):
        cols = re.durable.open_columns("d", man)
        # a straggled primary read defers to the replica pass — the
        # assembled columns stay bit-identical throughout
        for k in want:
            np.testing.assert_array_equal(cols[k], want[k], err_msg=k)
        sigs.extend(health.signals())
    assert health.straggler_reissues > 0
    assert any(s.kind == "straggler" and s.node == "beta" for s in sigs)


# ---------------------------------------------------------------------------
# Autopilot: health signals → priced rebalance decisions
# ---------------------------------------------------------------------------

def test_lost_node_triggers_autopilot_rebalance_decision(tmp_path):
    root = str(tmp_path / "store")
    sess = Session(store_path=root, num_workers=M,
                   cluster=ClusterConfig(nodes=NODES, replication=2))
    store = sess.store
    store.write("d", _data(seed=8))
    before = _canonical(store.read("d"))
    ap = sess.autopilot(clock=LogicalClock(),
                        config=AutopilotConfig(cooldown_ticks=0))

    # beta goes silent: alpha heartbeats, beta misses three ticks
    h = store.health
    for step in range(1, 5):
        h.heartbeat("alpha", step)
        h.tick(step)
    assert h.dead_nodes() == ["beta"]

    rep = ap.tick()
    applied = [a for a in rep.applied if a.kind == "rebalance"]
    assert len(applied) == 1
    a = applied[0]
    assert a.dataset == "*" and a.path == "rebalance"
    assert a.generation == 1            # the new placement epoch
    assert store.placement_epoch == 1
    assert store.directory.nodes == ("alpha",)

    # the decision and its why-record landed in decisions.log
    decs = store.durable.decisions()
    reb = [d for d in decs if d.get("kind") == "rebalance"]
    assert len(reb) == 1 and reb[0]["dataset"] == "*"
    whys = [r for d in decs if d.get("kind") == "why"
            for r in d["records"]]
    lost = [w for w in whys if w["action"] == "rebalance:node_lost"]
    assert len(lost) == 1 and lost[0]["accepted"]
    gate_names = [g["gate"] for g in lost[0]["gates"]]
    assert "mesh_replan" in gate_names and "surviving_nodes" in gate_names
    assert lost[0]["score"]["io_s"] >= 0

    # every partition serves from the survivor, bit-identically
    del sess, store
    shutil.rmtree(os.path.join(root, "nodes", "beta"))
    re = PartitionStore(root=root, num_workers=M)
    _assert_same(_canonical(re.read("d")), before)


def test_straggler_signal_prices_rebalance_with_worth_it_gate(tmp_path):
    sess = Session(store_path=str(tmp_path / "s"), num_workers=M,
                   cluster=ClusterConfig(nodes=("alpha", "beta", "gamma"),
                                         replication=3))
    store = sess.store
    store.write("d", _data(seed=9))
    ap = sess.autopilot(clock=LogicalClock(),
                        config=AutopilotConfig(cooldown_ticks=0))
    # a straggler signal with no observed runs prices benefit 0: the
    # worth_it gate must reject (a slow node is not worth a rebalance
    # nobody is waiting on), with the verdict in the why-record
    store.health._raise("straggler", "beta",
                        {"latency_s": 1.0, "threshold_s": 0.002,
                         "excess_s": 1.0, "detections": 3.0})
    rep = ap.tick()
    assert not any(a.kind == "rebalance" for a in rep.applied)
    w = next(r for r in rep.why if r["action"] == "rebalance:straggler")
    assert not w["accepted"]
    verdicts = {g["gate"]: g["passed"] for g in w["gates"]}
    assert verdicts["worth_it"] is False and verdicts["mesh_replan"] is True
    assert store.placement_epoch == 0


def test_lost_node_without_survivors_is_rejected(tmp_path):
    sess = Session(store_path=str(tmp_path / "s"), num_workers=M,
                   cluster=ClusterConfig(nodes=("solo",), replication=1))
    store = sess.store
    store.write("d", _data(seed=10))
    ap = sess.autopilot(clock=LogicalClock(),
                        config=AutopilotConfig(cooldown_ticks=0))
    for step in range(1, 5):
        store.health.tick(step)      # nobody heartbeats
    rep = ap.tick()
    assert not rep.applied
    w = next(r for r in rep.why if r["action"] == "rebalance:node_lost")
    verdicts = {g["gate"]: g["passed"] for g in w["gates"]}
    assert verdicts["surviving_nodes"] is False
    assert store.placement_epoch == 0


# ---------------------------------------------------------------------------
# Observability + planner integration
# ---------------------------------------------------------------------------

def test_cluster_metrics_and_rebalance_span(tmp_path):
    import gc
    gc.collect()      # drop earlier tests' stores off the shared registry
    obs.enable("full")
    try:
        sess = Session(store_path=str(tmp_path / "s"), num_workers=M,
                       cluster=ClusterConfig(nodes=NODES, replication=2))
        sess.store.write("d", _data(seed=11))
        res = sess.rebalance(add_nodes=("gamma",), reason="metrics-test")
        assert res.epoch == 1

        m = sess.metrics()["metrics"]
        for name in ("cluster_epoch", "cluster_nodes",
                     "cluster_directory_lookups_total",
                     "cluster_rebalances_total",
                     "cluster_rebalance_bytes_moved_total",
                     "cluster_rebalance_partitions_moved_total",
                     "cluster_parts_written_total",
                     "cluster_epoch_bumps_total",
                     "cluster_heartbeat_misses_total",
                     "cluster_straggler_reissues_total",
                     "cluster_nodes_alive"):
            assert name in m, name
        assert m["cluster_epoch"]["samples"][0]["value"] == 1.0
        assert m["cluster_rebalances_total"]["samples"][0]["value"] == 1.0
        assert m["cluster_nodes"]["samples"][0]["value"] == 3.0
        assert m["cluster_directory_lookups_total"]["samples"][0]["value"] > 0

        spans = {s.name for s in obs.finished_spans()}
        assert "cluster.rebalance" in spans
        assert "cluster.persist" in spans
        reb = next(s for s in obs.finished_spans()
                   if s.name == "cluster.rebalance")
        assert reb.args["epoch"] == 1
        assert "bytes_moved" in reb.args
    finally:
        obs.disable()
        obs.clear_spans()


def test_plan_cache_invalidated_by_placement_epoch(tmp_path):
    sess = Session(store_path=str(tmp_path / "s"), num_workers=M,
                   cluster=ClusterConfig(nodes=NODES, replication=2))
    tables = drift_tables(n_lineitem=600, n_orders=200, n_parts=50)
    for name in ("lineitem", "orders"):
        sess.store.write(name, tables[name])
    wl = q_orderkey()
    r1 = sess.run(wl)
    assert not r1.stats.plan_cache_hit
    r2 = sess.run(wl)
    assert r2.stats.plan_cache_hit
    sess.rebalance(add_nodes=("gamma",))
    # the placement epoch is pinned in the PlanKey: a rebalance re-plans
    r3 = sess.run(wl)
    assert not r3.stats.plan_cache_hit
    assert "placement: directory epoch 1" in r3.plan.explain()
