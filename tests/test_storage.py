"""Durable storage tier (DESIGN §10): segment/manifest round-trip,
crash-safety fallback, eviction/spill, cross-session shuffle elision."""

import os

import numpy as np
import pytest

from repro.core import Workload, enumerate_candidates
from repro.core.executor import TableVal
from repro.data.partition_store import PartitionStore
from repro.data.storage import RestoredPartitioner
from repro.data.storage.durable import DurableStore
from repro.data.storage.manifest import gen_dirname, manifest_filename
from repro.api import Session
from repro.service.observer import LogicalClock


def _keyed_candidate(dataset="d"):
    wl = Workload("w")
    ds = wl.scan(dataset)
    wl.partition(ds["k"])
    return enumerate_candidates(wl.graph, dataset)[0]


def _data(n=120, seed=0, dtype=np.int64):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, 37, size=n).astype(dtype),
            "v": np.arange(n, dtype=np.float32) + seed}


def _assert_datasets_equal(a, b):
    assert a.generation == b.generation
    assert a.num_rows == b.num_rows
    assert a.capacity == b.capacity
    np.testing.assert_array_equal(a.counts, b.counts)
    ga, gb = a.gather(), b.gather()
    assert set(ga) == set(gb)
    for k in ga:
        assert ga[k].dtype == gb[k].dtype
        np.testing.assert_array_equal(ga[k], gb[k])


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------

def test_roundtrip_bit_identical(tmp_path):
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root)
    ds = s.write("d", _data(), _keyed_candidate())
    s2 = PartitionStore.open(root)
    d2 = s2.read("d")
    assert d2.spilled                       # reopened columns are memmap views
    _assert_datasets_equal(ds, d2)
    # the partitioner identity survived: same Alg.4 signature set
    assert d2.partitioner.signature() == ds.partitioner.signature()


def test_restored_partitioner_matches_but_cannot_dispatch(tmp_path):
    root = str(tmp_path / "store")
    PartitionStore(num_workers=4, root=root).write("d", _data(),
                                                   _keyed_candidate())
    p = PartitionStore.open(root).read("d").partitioner
    assert isinstance(p, RestoredPartitioner)
    assert p.is_keyed
    assert p.signature_set() == _keyed_candidate().signature_set()
    with pytest.raises(ValueError, match="restored partitioner"):
        p.key_fn()


def test_roundtrip_device_columns(tmp_path):
    """A device-resident store persists through host views; reopening on
    either backend yields the same bits, and a device reopen prefetches
    the columns back onto the device on first read."""
    root = str(tmp_path / "store")
    dev = PartitionStore(num_workers=4, backend="device", root=root)
    ds = dev.write("d", _data(), _keyed_candidate())
    assert ds.backend == "device"

    host_view = PartitionStore.open(root)           # host backend reopen
    _assert_datasets_equal(ds.to_host(), host_view.read("d"))

    dev_view = PartitionStore.open(root, backend="device")
    got = dev_view.read("d")                        # read → host→device
    assert got.backend == "device"
    assert not got.spilled
    _assert_datasets_equal(ds.to_host(), got.to_host())


def test_unsafe_dataset_and_column_names_roundtrip(tmp_path):
    """Dataset and column names with path separators / odd characters are
    percent-encoded on disk — no crash, no directory escape."""
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root)
    ds = s.write("tenant/2026 events", {"user/id": np.arange(80),
                                        "v": np.arange(80.0)})
    got = PartitionStore.open(root).read("tenant/2026 events")
    _assert_datasets_equal(ds, got)
    assert set(got.gather()) == {"user/id", "v"}
    # nothing escaped the store root
    for dirpath, _dirs, _files in os.walk(str(tmp_path)):
        assert os.path.commonpath([dirpath, root]) == root \
            or dirpath == str(tmp_path)


def test_open_adopts_catalog_worker_count(tmp_path):
    root = str(tmp_path / "store")
    PartitionStore(num_workers=4, root=root).write("d", _data())
    s = PartitionStore.open(root, num_workers=16)
    assert s.m == 4                      # (m, capacity) layouts fix m


def test_generation_continuity_and_disk_retention(tmp_path):
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root, max_retired_generations=2)
    for i in range(4):
        s.write("d", _data(seed=i), _keyed_candidate())
    assert s.generation_of("d") == 3

    s2 = PartitionStore.open(root)
    assert s2.generation_of("d") == 3
    # a fresh process resolves retained generations from disk...
    old = s2.read("d", generation=2)
    _assert_datasets_equal(old, s.read("d", generation=2))
    # ...and GC pruned past the retention window
    ds_dir = os.path.join(root, "datasets", "d")
    assert not os.path.exists(os.path.join(ds_dir, manifest_filename(0)))
    assert not os.path.exists(os.path.join(ds_dir, gen_dirname(0)))
    # repartitions in the new process continue the generation sequence
    new, _ = s2.repartition(s2.read("d"), _keyed_candidate(), swap=True)
    assert new.generation == 4


# ---------------------------------------------------------------------------
# crash safety: every partial-write shape reopens to the prior generation
# ---------------------------------------------------------------------------

def _two_generations(root):
    s = PartitionStore(num_workers=4, root=root)
    g0 = s.write("d", _data(seed=1), _keyed_candidate())
    g1 = s.write("d", _data(seed=2), _keyed_candidate())
    return g0, g1


def test_truncated_segment_falls_back_bit_identically(tmp_path):
    root = str(tmp_path / "store")
    g0, g1 = _two_generations(root)
    seg = os.path.join(root, "datasets", "d", gen_dirname(1), "k.seg")
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) // 2)
    reopened = PartitionStore.open(root).read("d")
    assert reopened.generation == 0
    _assert_datasets_equal(g0, reopened)


def test_missing_manifest_falls_back(tmp_path):
    root = str(tmp_path / "store")
    g0, _ = _two_generations(root)
    os.remove(os.path.join(root, "datasets", "d", manifest_filename(1)))
    _assert_datasets_equal(g0, PartitionStore.open(root).read("d"))


def test_torn_manifest_falls_back(tmp_path):
    root = str(tmp_path / "store")
    g0, _ = _two_generations(root)
    man = os.path.join(root, "datasets", "d", manifest_filename(1))
    with open(man, "w") as f:
        f.write('{"name": "d", "gener')        # torn mid-write
    _assert_datasets_equal(g0, PartitionStore.open(root).read("d"))


def test_missing_current_pointer_recovers_latest(tmp_path):
    root = str(tmp_path / "store")
    _, g1 = _two_generations(root)
    os.remove(os.path.join(root, "datasets", "d", "CURRENT"))
    _assert_datasets_equal(g1, PartitionStore.open(root).read("d"))


def test_leftover_tmp_files_are_ignored(tmp_path):
    root = str(tmp_path / "store")
    _, g1 = _two_generations(root)
    ds_dir = os.path.join(root, "datasets", "d")
    for junk in ("CURRENT.tmp", manifest_filename(2) + ".tmp",
                 os.path.join(gen_dirname(1), "v.seg.tmp")):
        with open(os.path.join(ds_dir, junk), "w") as f:
            f.write("partial")
    _assert_datasets_equal(g1, PartitionStore.open(root).read("d"))


def test_empty_root_opens_empty(tmp_path):
    s = PartitionStore.open(str(tmp_path / "fresh"))
    assert s.datasets == {}
    assert s.is_durable


# ---------------------------------------------------------------------------
# property: dtype/shape round-trip through segment files
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # dev extra missing
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _DTYPES = [np.int64, np.int32, np.int16, np.uint8,
               np.float64, np.float32]

    @given(st.integers(2, 8),
           st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=120),
           st.sampled_from(_DTYPES),
           st.integers(0, 3),
           st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_segment_roundtrip_property(tmp_path_factory, m, keys, vdtype,
                                        inner, device):
        """Any dtype/shape written through the store round-trips through
        segment files bit-identically — including device-backed columns
        (persisted via their host views)."""
        tmp = tmp_path_factory.mktemp("seg")
        root = str(tmp / "store")
        keys = np.asarray(keys, np.int64)
        n = keys.shape[0]
        shape = (n,) if inner == 0 else (n, inner)
        if np.issubdtype(vdtype, np.integer):
            vals = (np.arange(np.prod(shape)) % 251).astype(
                vdtype).reshape(shape)
        else:
            vals = (np.arange(np.prod(shape), dtype=np.float64)
                    * 0.37).astype(vdtype).reshape(shape)
        store = PartitionStore(num_workers=m, root=root,
                               backend="device" if device else "host")
        ds = store.write("d", {"k": keys, "v": vals}, _keyed_candidate())
        got = PartitionStore.open(root).read("d")
        _assert_datasets_equal(ds.to_host(), got)
        g = got.gather()
        assert g["v"].dtype == np.dtype(vdtype)
        assert g["v"].shape == shape
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_segment_roundtrip_property():
        pass


# ---------------------------------------------------------------------------
# eviction loop
# ---------------------------------------------------------------------------

def test_spill_and_rehydrate_bit_identical(tmp_path):
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root)
    ds = s.write("d", _data(400))
    before = {k: np.array(v) for k, v in ds.gather().items()}
    assert s.spill("d")
    assert s.is_spilled("d")
    assert s.resident_bytes() == 0
    after = s.read("d").gather()             # lazy memmap read-through
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    assert s.prefetch("d")
    assert not s.is_spilled("d")
    assert s.resident_bytes() > 0
    io = s.io_snapshot()
    assert io["spills"] == 1 and io["rehydrations"] == 1
    assert io["rehydrated_bytes"] > 0


def test_memory_budget_evicts_coldest_first(tmp_path):
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root)
    s.write("a", {"x": np.arange(400, dtype=np.float64)})
    s.write("b", {"x": np.arange(400, dtype=np.float64)})
    per_ds = s.resident_bytes() // 2
    s.read("a")                              # a is now hotter than b
    s.memory_budget_bytes = per_ds + per_ds // 2   # room for one dataset
    assert s._maybe_evict() == 1
    assert s.is_spilled("b") and not s.is_spilled("a")
    assert s.resident_bytes() <= s.memory_budget_bytes


def test_budget_on_write_keeps_store_under_budget(tmp_path):
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root, memory_budget_bytes=2000)
    for i in range(4):
        s.write(f"d{i}", {"x": np.arange(300, dtype=np.float64) + i})
    assert s.resident_bytes() <= 2000
    assert any(s.is_spilled(f"d{i}") for i in range(4))
    for i in range(4):                       # everything still readable
        got = np.sort(s.read(f"d{i}").gather()["x"])
        np.testing.assert_array_equal(got, np.arange(300, dtype=np.float64) + i)


def test_zero_size_column_does_not_wedge_eviction(tmp_path):
    """A (n, 0) column can't be memmapped; it must not keep its dataset
    'resident' forever (which would spin the eviction loop)."""
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root)
    s.write("z", {"k": np.arange(64, dtype=np.int64),
                  "empty": np.zeros((64, 0), np.float32)})
    s.write("big", {"x": np.arange(600, dtype=np.float64)})
    s.memory_budget_bytes = 8           # force eviction of everything
    s._maybe_evict()                    # must terminate
    assert s.is_spilled("z") and s.is_spilled("big")
    got = s.read("z").gather()
    assert got["empty"].shape == (64, 0)
    np.testing.assert_array_equal(np.sort(got["k"]), np.arange(64))


def test_budget_counts_and_spills_retired_generations(tmp_path):
    """Superseded-but-retained generations hold real memory; the budget
    sees them and the eviction loop spills them first — without moving
    the CURRENT pointer backwards."""
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root)
    s.write("d", _data(400, seed=1))
    base = s.resident_bytes()
    s.write("d", _data(400, seed=2), _keyed_candidate())   # gen0 retired
    assert s.resident_bytes() > base    # retired gen counted
    s.memory_budget_bytes = base + base // 2
    s._maybe_evict()
    assert all(old.spilled for old in s._retired["d"])
    assert not s.is_spilled("d")        # current generation stayed hot
    assert s.resident_bytes() <= s.memory_budget_bytes
    # CURRENT still points at the newest generation
    assert PartitionStore.open(root).generation_of("d") == 1


def test_device_read_prefetches_spilled_dataset(tmp_path):
    root = str(tmp_path / "store")
    PartitionStore(num_workers=4, backend="device",
                   root=root).write("d", _data(), _keyed_candidate())
    s = PartitionStore.open(root, backend="device")
    assert s.datasets["d"].spilled           # attached cold
    got = s.read("d")                        # device backend → prefetch
    assert got.backend == "device"
    assert s.io_snapshot()["rehydrations"] == 1


# ---------------------------------------------------------------------------
# manual flush / dirty tracking
# ---------------------------------------------------------------------------

def test_autoflush_off_requires_flush(tmp_path):
    root = str(tmp_path / "store")
    s = PartitionStore(num_workers=4, root=root, autoflush=False)
    ds = s.write("d", _data(), _keyed_candidate())
    assert PartitionStore.open(root).datasets == {}    # nothing durable yet
    assert s.flush() == 1
    _assert_datasets_equal(ds, PartitionStore.open(root).read("d"))
    assert s.flush() == 0                    # idempotent: already published


# ---------------------------------------------------------------------------
# bounded write_log (satellite)
# ---------------------------------------------------------------------------

def test_write_log_bounded_with_monotone_totals():
    s = PartitionStore(num_workers=4, write_log_cap=4)
    total_bytes = 0
    for i in range(10):
        ds = s.write(f"d{i % 2}", _data(60, seed=i))
        total_bytes += ds.nbytes
    assert len(s.write_log) == 4
    t = s.write_stats()
    assert t["entries"] == 10 and t["evicted"] == 6
    assert t["bytes"] == total_bytes         # aggregates cover evicted rows
    assert t["rows"] == 10 * 60
    # most-recent entries survive (optimizer reads write_log[-1])
    assert s.write_log[-1]["generation"] == s.generation_of("d1")


# ---------------------------------------------------------------------------
# vectorized gather (satellite): order matches the per-worker loop
# ---------------------------------------------------------------------------

def test_gather_order_matches_worker_loop():
    s = PartitionStore(num_workers=5)
    ds = s.write("d", _data(333, seed=7), _keyed_candidate())
    ref = {}
    for k, v in ds.columns.items():
        v = np.asarray(v)
        ref[k] = np.concatenate(
            [v[w, :ds.counts[w]] for w in range(ds.num_workers)], axis=0)
    got = ds.gather()
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])
        assert ref[k].dtype == got[k].dtype


# ---------------------------------------------------------------------------
# cost model I/O charging + executor I/O stats
# ---------------------------------------------------------------------------

def test_cost_model_charges_spill_and_persist_io():
    from repro.service.cost_model import WhatIfCostModel
    from repro.core.history import HistoryStore

    cm = WhatIfCostModel()
    cm.observe_io(1e9, 1.0)                  # measured 1 GB/s storage
    assert cm.io_throughput() == pytest.approx(1e9)
    assert cm.io_seconds(2e9) == pytest.approx(2.0)

    hist = HistoryStore()
    wl = Workload("consumer")
    t = wl.scan("d")
    wl.partition(t["k"])
    for ts in (1.0, 2.0, 3.0):
        hist.log_workload(wl, timestamp=ts, latency=1.0)
    cand = _keyed_candidate()
    kw = dict(history=hist, now=4.0)
    base = cm.score("d", 1e9, 4, cand, None, **kw)
    dur = cm.score("d", 1e9, 4, cand, None, durable=True, **kw)
    spilled = cm.score("d", 1e9, 4, cand, None, durable=True,
                       source_spilled=True, **kw)
    assert base.io_s == 0.0
    assert dur.io_s == pytest.approx(1.0)            # persist new generation
    assert spilled.io_s == pytest.approx(2.0)        # + rehydrate source
    assert dur.apply_cost_s > base.apply_cost_s
    # the gate prices I/O: same benefit (1.8s here) clears the in-memory
    # bar but not the durable one at hysteresis=2, horizon=1
    assert base.worth_it(2.0, 1.0)
    assert not dur.worth_it(2.0, 1.0)


def test_executor_reports_storage_io(tmp_path):
    sess = Session(store_path=str(tmp_path / "store"), num_workers=4)
    sess.write("events", _data(200))
    wl = Workload("w")
    t = wl.scan("events")
    p = wl.partition(t["k"])
    wl.write(p, "out")
    res = sess.run(wl)
    assert res.stats.storage_io_bytes > 0    # autoflushed "out" generation
    assert res.stats.storage_io_s > 0

    mem = Session(num_workers=4)
    mem.write("events", _data(200))
    res2 = mem.run(wl)
    assert res2.stats.storage_io_bytes == 0  # memory-only store


# ---------------------------------------------------------------------------
# the headline scenario: Autopilot layout reused by a fresh process
# ---------------------------------------------------------------------------

def _consumer():
    wl = Workload("consumer")
    t = wl.scan("events")
    p = wl.partition(t["k"])
    wl.aggregate(p, reducer="sum")
    return wl


def _final_table(res):
    return [v for v in res.values.values() if isinstance(v, TableVal)][-1]


def test_cross_session_layout_reuse_elides_shuffle(tmp_path):
    root = str(tmp_path / "store")
    # process A: round-robin write, observed runs, Autopilot applies layout
    a = Session(store_path=root, num_workers=4)
    a.write("events", _data(800, seed=3))
    ap = a.autopilot(clock=LogicalClock())
    first = a.run(_consumer())
    assert first.stats.shuffles_performed == 1
    a.run(_consumer())
    rep = ap.tick()
    assert [d.dataset for d in rep.applied] == ["events"]
    res_a = a.run(_consumer())
    assert res_a.stats.shuffles_elided == 1

    # the applied decision is in the durable catalog
    decisions = a.store.durable.decisions()
    assert decisions and decisions[-1]["dataset"] == "events"
    assert decisions[-1]["candidate"] == rep.applied[0].decision \
        .candidate.signature()

    # process B (fresh Session, no shared state): reopen → zero-shuffle
    b = Session(store_path=root)
    assert b.num_workers == 4
    res_b = b.run(_consumer())
    assert res_b.stats.shuffles_elided == 1
    assert res_b.stats.shuffles_performed == 0
    assert res_b.stats.shuffle_bytes == 0
    ta, tb = _final_table(res_a), _final_table(res_b)
    np.testing.assert_array_equal(ta.counts, tb.counts)
    for k in ta.columns:
        got = np.asarray(tb.columns[k])
        np.testing.assert_array_equal(np.asarray(ta.columns[k]), got)
        assert np.asarray(ta.columns[k]).dtype == got.dtype


def test_decision_log_survives_reopen(tmp_path):
    root = str(tmp_path / "store")
    d = DurableStore(root, num_workers=4)
    d.log_decision({"dataset": "d", "generation": 1})
    d.log_decision({"dataset": "d", "generation": 2})
    with open(d.decisions_path, "a") as f:
        f.write('{"torn":')                  # crash mid-append
    got = DurableStore(root).decisions()
    assert [r["generation"] for r in got] == [1, 2]


def test_session_store_and_store_path_exclusive(tmp_path):
    with pytest.raises(ValueError, match="store= or store_path="):
        Session(store=PartitionStore(num_workers=2),
                store_path=str(tmp_path / "s"))


def test_plan_cache_pins_valid_across_restart(tmp_path):
    """The plan cache key pins (dataset, generation, partitioner sig); a
    reattached store resolves the same pins, so the first run of process B
    compiles against the restored generation and subsequent runs hit."""
    root = str(tmp_path / "store")
    a = Session(store_path=root, num_workers=4)
    a.write("events", _data(300), _keyed_candidate("events"))
    key_a = a.planner.plan_key(_consumer(), "host")

    b = Session(store_path=root)
    key_b = b.planner.plan_key(_consumer(), "host")
    assert key_a.layout == key_b.layout
    b.run(_consumer())
    res = b.run(_consumer())
    assert res.stats.plan_cache_hit is True
