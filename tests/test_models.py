"""Per-arch smoke + decode-consistency tests (reduced configs, CPU)."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.reduced import reduced
from repro.models import transformer as T

ARCHS = list_archs()


def _setup(arch, seed=0, big_capacity=True):
    cfg = reduced(get_config(arch))
    if cfg.moe and big_capacity:
        # avoid capacity drops so decode matches full forward exactly
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, key)
    return cfg, params, key


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg, params, key = _setup(arch)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.encoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.num_frames, cfg.d_model))
    logits, _, _ = T.forward(cfg, params, tokens,
                             frames=batch.get("frames"), mode="train")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    # one optimizer step moves the loss
    from repro.launch.steps import make_optimizer, make_train_step
    opt = make_optimizer(replace(cfg, accum_steps=1), peak_lr=1e-2,
                         total_steps=10)
    step = make_train_step(replace(cfg, accum_steps=1), opt)
    state = {"params": params, "opt": opt.init(params)}
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg, params, key = _setup(arch, seed=1)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    frames = (jax.random.normal(key, (B, cfg.encoder.num_frames, cfg.d_model))
              if cfg.encoder else None)
    logits_full, _, _ = T.forward(cfg, params, toks, frames=frames,
                                  mode="train")
    lg_prefill, cache = T.prefill(cfg, params, toks[:, :S], frames=frames,
                                  cache_len=S + 8)
    err1 = np.abs(np.asarray(lg_prefill)
                  - np.asarray(logits_full[:, S - 1])).max()
    lg_dec, new_cache = T.decode_step(cfg, params, cache, toks[:, S:S + 1],
                                      jnp.int32(S))
    err2 = np.abs(np.asarray(lg_dec) - np.asarray(logits_full[:, S])).max()
    assert err1 < 2e-3, f"{arch} prefill mismatch {err1}"
    assert err2 < 2e-3, f"{arch} decode mismatch {err2}"


def test_moe_capacity_drop_is_only_decode_divergence():
    """With cf=1.25 (paper-realistic) the decode/full divergence comes from
    capacity dropping alone — validated hypothesis from development."""
    cfg, params, key = _setup("deepseek-v2-236b", big_capacity=False)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    logits_full, _, _ = T.forward(cfg, params, toks, mode="train")
    lg_prefill, _ = T.prefill(cfg, params, toks[:, :S], cache_len=S + 8)
    err = np.abs(np.asarray(lg_prefill)
                 - np.asarray(logits_full[:, S - 1])).max()
    # prefill sees the same token population → same drops up to float-order
    # ties at the capacity boundary (different einsum fusion between paths)
    assert err < 5e-2


def test_gradients_flow_everywhere():
    cfg, params, key = _setup("recurrentgemma-9b")
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    grads = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
    norms = jax.tree.map(lambda g: float(jnp.abs(g).max()), grads)
    leaves = jax.tree.leaves(norms)
    assert all(np.isfinite(l) for l in leaves)
    assert sum(1 for l in leaves if l > 0) > len(leaves) * 0.7


def test_param_counts_match_analytic():
    """init_params leaf sizes must sum to ArchConfig.param_count()."""
    for arch in ("internlm2-1.8b", "mamba2-370m"):
        cfg = get_config(arch)
        struct = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        total = sum(int(np.prod(l.shape))
                    for l in jax.tree.leaves(struct))
        analytic = cfg.param_count()
        # norms/biases/positional are not in the analytic count — ≤1.5% slack
        assert abs(total - analytic) / analytic < 0.015, arch
