"""Single-pass device shuffle: counting-sort kernels, dispatch-plan cache,
and the device-to-device repartition fast path (DESIGN §5).

No hypothesis dependency — these run even in the bare container.  The
hypothesis property sweeps live in test_shuffle_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine, author_integrator, enumerate_candidates
from repro.core.engine import TableVal
from repro.data import device_repartition as dr
from repro.data.partition_store import (PartitionStore, _counting_sort_dest,
                                        _presorted_dest)
from repro.kernels.hash_partition.hash_partition import (hash_partition_padded,
                                                         scatter_perm)
from repro.kernels.hash_partition.ref import (hash_partition_padded_ref,
                                              hash_partition_ref,
                                              scatter_perm_ref)


# -- counting-sort kernels vs oracles ----------------------------------------

@pytest.mark.parametrize("n,m,block", [(100, 8, 64), (1000, 13, 256),
                                       (7, 4, 8), (4096, 32, 1024)])
def test_scatter_perm_matches_oracle(n, m, block):
    keys = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, 2 ** 31 - 1,
                              jnp.int32)
    pids, counts = hash_partition_ref(keys, m)
    got = scatter_perm(pids, counts, block=block, interpret=True)
    want = scatter_perm_ref(pids, counts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # a valid permutation: every destination slot hit exactly once
    assert np.array_equal(np.sort(np.asarray(got)), np.arange(n))


def test_scatter_perm_is_stable_counting_sort():
    """dest must equal the inverse of the *stable* argsort — equal pids keep
    their input order (the bit-identical guarantee hangs on this)."""
    pids = jnp.asarray(np.array([2, 0, 2, 1, 0, 2, 0], np.int32))
    counts = jnp.asarray(np.bincount(np.asarray(pids), minlength=3)
                         .astype(np.int32))
    dest = np.asarray(scatter_perm(pids, counts, block=8, interpret=True))
    order = np.argsort(np.asarray(pids), kind="stable")
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    np.testing.assert_array_equal(dest, inv)


@pytest.mark.parametrize("n,B,m", [(100, 128, 8), (1000, 1024, 13),
                                   (8, 8, 4), (5000, 8192, 32)])
def test_hash_partition_padded_matches_oracle(n, B, m):
    keys = jax.random.randint(jax.random.PRNGKey(1), (B,), 0, 2 ** 31 - 1,
                              jnp.int32)
    kp, kc = hash_partition_padded(keys, jnp.int32(n), m, block=256,
                                   interpret=True)
    rp, rc = hash_partition_padded_ref(keys, jnp.int32(n), m)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    assert int(kc[m]) == B - n                      # overflow bucket size
    assert int(kc[:m].sum()) == n


# -- host counting-sort placement (vectorized dispatch) ----------------------

def test_counting_sort_dest_matches_worker_loop():
    rng = np.random.default_rng(3)
    m, n = 7, 501
    pids = rng.integers(0, m, n)
    counts = np.bincount(pids, minlength=m)
    cap = int(counts.max())
    dest = _counting_sort_dest(pids, counts, cap)

    v = rng.normal(size=n).astype(np.float32)
    buf = np.zeros(m * cap, np.float32)
    buf[dest] = v
    # reference: per-worker copy loop (the pre-vectorization baseline)
    order = np.argsort(pids, kind="stable")
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    want = np.zeros((m, cap), np.float32)
    sv = v[order]
    for w in range(m):
        c = counts[w]
        if c:
            want[w, :c] = sv[offsets[w]:offsets[w] + c]
    np.testing.assert_array_equal(buf.reshape(m, cap), want)


def test_presorted_dest_matches_segmented_loop():
    counts = np.array([3, 0, 5, 2], np.int64)
    cap = int(counts.max())
    dest = _presorted_dest(counts, cap)
    n = int(counts.sum())
    v = np.arange(n, dtype=np.int32)
    buf = np.zeros(4 * cap, np.int32)
    buf[dest] = v
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    want = np.zeros((4, cap), np.int32)
    for w in range(4):
        c = counts[w]
        if c:
            want[w, :c] = v[offsets[w]:offsets[w] + c]
    np.testing.assert_array_equal(buf.reshape(4, cap), want)


# -- dispatch-plan cache: no retrace across repeated same-shape shuffles -----

def test_store_write_same_shape_traces_once():
    """Repeated PartitionStore.write calls of the same shape must trigger
    exactly one trace of the scatter plan (ISSUE 2 acceptance) — including
    writes whose key skew (and therefore capacity = counts.max()) differs,
    since capacity rides the plan as a traced scalar, not a cache key."""
    wl, _ = _reddit_like()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    dr.clear_plan_cache()
    store = PartitionStore(8, backend="device")
    rng = np.random.default_rng(0)

    def batch(seed):
        r = np.random.default_rng(seed)
        skew = 40 if seed % 2 else 60         # different counts.max() per seed
        return {"author": r.integers(0, skew, 2000).astype(np.int64),
                "score": r.normal(size=2000).astype(np.float32)}

    caps = []
    store.write("a", batch(0), cand)
    caps.append(store.read("a").capacity)
    t1 = dr.plan_cache_stats()["traces"]
    for i in range(4):
        store.write(f"b{i}", batch(i + 1), cand)
        caps.append(store.read(f"b{i}").capacity)
    stats = dr.plan_cache_stats()
    assert len(set(caps)) > 1, "test needs varying capacities to be real"
    # capacities differ but land in one output-row bucket — no retrace
    assert len({dr.shape_bucket(8 * c) for c in caps}) == 1, caps
    assert stats["traces"] == t1, f"retraced: {stats}"
    assert stats["calls"] >= 5


def test_rebucket_shape_bucket_shares_trace():
    """Different Ns inside one power-of-two bucket reuse the same plan and
    trace — the shape-bucket half of the retrace-free guarantee."""
    dr.clear_plan_cache()
    rng = np.random.default_rng(1)
    for n in (900, 1000, 1024):            # all bucket to B=1024
        assert dr.shape_bucket(n) == 1024
        cols = {"v": rng.normal(size=n).astype(np.float32)}
        keys = rng.integers(0, 10_000, n).astype(np.int64)
        got, counts = dr.device_rebucket(cols, keys, 8)
        assert int(counts.sum()) == n
    stats = dr.plan_cache_stats()
    assert stats["plans"] == 1 and stats["traces"] == 1, stats


def test_rebucket_bit_identical_inside_bucket():
    """Padding rows introduced by the shape bucket must never leak into the
    output — n=900 inside a 1024 bucket matches the host path exactly."""
    from repro.core.ir import _mix_hash
    rng = np.random.default_rng(2)
    n, m = 900, 11
    cols = {"v": rng.normal(size=n).astype(np.float32),
            "i": rng.integers(0, 9, n).astype(np.int32),
            "d": rng.normal(size=n)}                     # float64: hybrid
    keys = rng.integers(0, 5_000, n).astype(np.int64)
    got, counts = dr.device_rebucket(cols, keys, m)
    pids = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % m
    order = np.argsort(pids, kind="stable")
    np.testing.assert_array_equal(counts, np.bincount(pids, minlength=m))
    for k, v in cols.items():
        assert got[k].dtype == v.dtype
        np.testing.assert_array_equal(got[k], v[order])


@pytest.mark.parametrize("use_kernel", [True, False])
def test_fused_mode_matches_hostperm(use_kernel):
    """The TPU-default fused plan (everything in one jit, kernels in
    interpret mode on CPU) ≡ the CPU-default hostperm plan ≡ the host numpy
    path — the mode switch must never change a bit."""
    from repro.core.ir import _mix_hash
    rng = np.random.default_rng(6)
    n, m = 700, 9
    cols = {"v": rng.normal(size=n).astype(np.float32),
            "d": rng.normal(size=n),                     # float64: hybrid
            "i": rng.integers(0, 7, n).astype(np.int32)}
    keys = rng.integers(0, 3_000, n).astype(np.int64)
    got_f, counts_f = dr.device_rebucket(cols, keys, m, mode="fused",
                                         interpret=True,
                                         use_kernel=use_kernel)
    got_h, counts_h = dr.device_rebucket(cols, keys, m, mode="hostperm")
    pids = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % m
    order = np.argsort(pids, kind="stable")
    np.testing.assert_array_equal(counts_f, counts_h)
    np.testing.assert_array_equal(counts_f, np.bincount(pids, minlength=m))
    for k, v in cols.items():
        assert got_f[k].dtype == v.dtype and got_h[k].dtype == v.dtype
        np.testing.assert_array_equal(got_f[k], v[order])
        np.testing.assert_array_equal(got_h[k], v[order])

    # scatter side: same (m, cap, ...) layout from both modes
    pids_d, hist = dr.device_partition_ids(keys, m)
    counts = np.asarray(hist).astype(np.int64)
    sc_f = dr.device_scatter_padded(cols, pids_d, counts, mode="fused",
                                    interpret=True, use_kernel=use_kernel)
    sc_h = dr.device_scatter_padded(cols, pids_d, counts, mode="hostperm")
    for k in cols:
        assert np.asarray(sc_f[k]).dtype == np.asarray(sc_h[k]).dtype
        np.testing.assert_array_equal(np.asarray(sc_f[k]),
                                      np.asarray(sc_h[k]), err_msg=k)


def test_chained_rebucket_relays_fresh_key():
    """Chained device repartitions: the relayed device_columns carry the
    previous shuffle's __key__, which must never shadow the key the next
    node partitions on (regression — the stale device copy used to win)."""
    from repro.core.ir import _mix_hash
    rng = np.random.default_rng(8)
    n, m = 600, 7
    cols = {"v": rng.normal(size=n).astype(np.float32)}
    key1 = rng.integers(0, 500, n).astype(np.int32)
    key2 = rng.integers(0, 500, n).astype(np.int32)

    res1 = dr.device_rebucket_full(cols, key1, m)
    assert res1.device_columns and "__key__" in res1.device_columns
    # second shuffle on a different key, relaying the first one's flats
    key2_shuffled = key2[_stable_order(key1, m)]
    res2 = dr.device_rebucket_full(res1.columns, key2_shuffled, m,
                                   device_columns=res1.device_columns)
    order2 = _stable_order(key2_shuffled, m)
    np.testing.assert_array_equal(res2.columns["__key__"],
                                  key2_shuffled[order2])
    np.testing.assert_array_equal(res2.columns["v"],
                                  res1.columns["v"][order2])


def _stable_order(keys, m):
    from repro.core.ir import _mix_hash
    pids = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % m
    return np.argsort(pids, kind="stable")


# -- capacity validation ------------------------------------------------------

def test_hash_pids_jit_buckets_device_keys():
    """Device-resident keys are padded to the shape bucket before the
    elementwise hash jit, so varying N never retraces it (regression)."""
    before = dr._hash_pids_jit._cache_size()
    for n in (900, 950, 1000):                 # same 1024 bucket
        keys = jnp.asarray(np.arange(n, dtype=np.int32))
        pids, counts = dr.shuffle_pids(keys, 8, mode="hostperm")
        assert pids.shape == (n,) and int(counts.sum()) == n
    assert dr._hash_pids_jit._cache_size() <= before + 1


def test_empty_device_write_stays_device_backed():
    """A 0-row write to a device store must still produce a device-backed
    dataset (round-trippable dtypes), so it keeps the d2d path downstream."""
    store = PartitionStore(4, backend="device")
    ds = store.write("e", {"v": np.zeros(0, np.float32),
                           "d": np.zeros(0, np.float64)})
    assert ds.backend == "device"
    assert isinstance(ds.columns["v"], jax.Array)
    assert isinstance(ds.columns["d"], np.ndarray)     # 64-bit stays host
    assert ds.capacity == 1 and ds.num_rows == 0


def test_scatter_padded_small_capacity_raises():
    """ISSUE 2 satellite: explicit capacity < counts.max() used to silently
    clamp/drop rows inside the scatter — now it must raise."""
    rng = np.random.default_rng(4)
    n, m = 300, 4
    data = {"k": rng.integers(0, 50, n).astype(np.int64)}
    pids, hist = dr.device_partition_ids(data["k"], m)
    counts = np.asarray(hist).astype(np.int64)
    with pytest.raises(ValueError, match="capacity"):
        dr.device_scatter_padded(data, pids, counts,
                                 capacity=int(counts.max()) - 1)
    # exact capacity stays legal
    cols = dr.device_scatter_padded(data, pids, counts,
                                    capacity=int(counts.max()))
    assert np.asarray(cols["k"]).shape == (m, int(counts.max()))


# -- device-to-device repartition --------------------------------------------

def _reddit_like(n_sub=3000, n_auth=500, seed=0):
    rng = np.random.default_rng(seed)
    subs = {"author": rng.integers(0, n_auth, n_sub).astype(np.int64),
            "score": rng.normal(size=n_sub).astype(np.float32),
            "ups": rng.integers(0, 1000, n_sub).astype(np.int32)}
    return author_integrator(), {"submissions": subs}


def test_d2d_repartition_matches_host_and_skips_gather(monkeypatch):
    wl, tables = _reddit_like()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    data = tables["submissions"]

    host = PartitionStore(8)
    dev = PartitionStore(8, backend="device")
    ds_h = host.write("submissions", data)
    ds_d = dev.write("submissions", data)

    # the fast path must never call the host gather
    monkeypatch.setattr(type(ds_d), "gather",
                        _raise_gather(type(ds_d).gather), raising=True)
    new_d, moved_d = dev.repartition(ds_d, cand)
    monkeypatch.undo()
    new_h, moved_h = host.repartition(ds_h, cand)

    assert dev.write_log[-1]["path"] == "d2d"
    assert new_d.backend == "device"
    assert moved_h == moved_d
    np.testing.assert_array_equal(new_h.counts, new_d.counts)
    flat_h, flat_d = new_h.gather(), new_d.gather()
    for k in flat_h:
        assert flat_h[k].dtype == flat_d[k].dtype
        np.testing.assert_array_equal(flat_h[k], flat_d[k])


def _raise_gather(orig):
    def gather(self):
        raise AssertionError("d2d fast path must not host-gather")
    return gather


def test_d2d_repartition_stays_mesh_placed():
    from jax.sharding import Mesh
    from repro.core.sharding_bridge import sharding_for
    wl, tables = _reddit_like(n_sub=400, n_auth=64)
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    dev = PartitionStore(8, backend="device")
    ds = dev.write("submissions", tables["submissions"])
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    new, _ = dev.repartition(ds, cand, mesh=mesh)
    assert isinstance(new.columns["score"], jax.Array)
    assert new.columns["score"].sharding == sharding_for(mesh,
                                                         new.partitioner)
    assert dev.read(new.name) is new        # placement persisted in the store


def test_flatten_dataset_matches_gather():
    wl, tables = _reddit_like(n_sub=777, n_auth=99, seed=5)
    dev = PartitionStore(6, backend="device")
    ds = dev.write("submissions", tables["submissions"])
    flat_ref = ds.gather()
    flat_dev = dr.flatten_dataset(ds)
    for k in flat_ref:
        np.testing.assert_array_equal(np.asarray(flat_dev[k]), flat_ref[k])
    dev_only = dr.device_flat_columns(ds)
    assert dev_only and all(isinstance(v, jax.Array)
                            for v in dev_only.values())
    for k, v in dev_only.items():
        np.testing.assert_array_equal(np.asarray(v), flat_ref[k])


# -- engine d2d relay ---------------------------------------------------------

def test_engine_device_store_bit_identical_and_relays_device_columns():
    """Device store + device engine ≡ host store + host engine, and the scan
    seeds the partition node with device-resident flats (the d2d relay)."""
    wl, tables = _full_reddit_case()
    host = PartitionStore(8)
    dev = PartitionStore(8, backend="device")
    for name, data in tables.items():
        host.write(name, data)
        dev.write(name, data)
    vals_h, _ = Engine(host, backend="host").run(wl)
    wl2, _ = _full_reddit_case()
    vals_d, stats_d = Engine(dev, backend="device").run(wl2)
    assert stats_d.device_repartitions > 0
    for nid, h in vals_h.items():
        if not isinstance(h, TableVal):
            continue
        d = vals_d[nid]
        np.testing.assert_array_equal(h.counts, d.counts)
        for k in h.columns:
            assert h.columns[k].dtype == d.columns[k].dtype, (nid, k)
            np.testing.assert_array_equal(h.columns[k], d.columns[k],
                                          err_msg=f"node {nid} col {k}")
    # the repartitioned tables carry device flats forward
    relayed = [v for v in vals_d.values()
               if isinstance(v, TableVal) and v.device_columns]
    assert relayed, "no TableVal carried device_columns through the run"
    for tv in relayed:
        for k, v in tv.device_columns.items():
            assert isinstance(v, jax.Array)
            np.testing.assert_array_equal(np.asarray(v), tv.columns[k])


def _full_reddit_case(n_sub=2500, n_auth=400, seed=0):
    rng = np.random.default_rng(seed)
    subs = {"author": rng.integers(0, n_auth, n_sub).astype(np.int64),
            "score": rng.normal(size=n_sub).astype(np.float32),
            "ups": rng.integers(0, 1000, n_sub).astype(np.int32)}
    auths = {"author": np.arange(n_auth, dtype=np.int64),
             "karma": rng.normal(size=n_auth).astype(np.float32)}
    return author_integrator(), {"submissions": subs, "authors": auths}
