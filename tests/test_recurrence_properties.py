"""Property tests: recurrent decode == scan outputs, step by step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.models.rglru import rglru_block, rglru_init
from repro.models.ssd import ssd_block, ssd_init


@given(st.integers(0, 10_000), st.integers(6, 20))
@settings(max_examples=8, deadline=None)
def test_ssd_decode_matches_scan(seed, T):
    """Prefill over T tokens then per-token decode == full scan, at every
    position (the state-space duality, empirically)."""
    key = jax.random.PRNGKey(seed)
    D, d_inner, state, H, chunk = 16, 32, 8, 4, 4
    p = ssd_init(key, D, d_inner=d_inner, state=state, nheads=H,
                 conv_width=4, dtype=jnp.float32)
    x = jax.random.normal(key, (1, T, D)) * 0.5
    kw = dict(d_inner=d_inner, state=state, nheads=H, chunk=chunk)
    y_full, _ = ssd_block(p, x, **kw)
    split = T // 2
    y_a, st_ = ssd_block(p, x[:, :split], return_final_state=True, **kw)
    ys = [y_a]
    for t in range(split, T):
        y_t, st_ = ssd_block(p, x[:, t:t + 1], rec_state=st_, **kw)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               atol=2e-4, rtol=2e-4)


@given(st.integers(0, 10_000), st.integers(6, 24))
@settings(max_examples=8, deadline=None)
def test_rglru_decode_matches_scan(seed, T):
    key = jax.random.PRNGKey(seed)
    D, W = 12, 16
    p = rglru_init(key, D, width=W, conv_width=4, dtype=jnp.float32)
    x = jax.random.normal(key, (2, T, D)) * 0.5
    y_full, _ = rglru_block(p, x)
    split = T // 2
    y_a, st_ = rglru_block(p, x[:, :split], return_final_state=True)
    ys = [y_a]
    for t in range(split, T):
        y_t, st_ = rglru_block(p, x[:, t:t + 1], state=st_)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               atol=2e-4, rtol=2e-4)
