"""PartitionStore invariants (hypothesis property tests)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import Workload, enumerate_candidates
from repro.core.partitioner import (PartitionerCandidate, RANDOM,
                                    ROUND_ROBIN)
from repro.data.partition_store import PartitionStore


def _keyed_candidate():
    wl = Workload("w")
    ds = wl.scan("d")
    wl.partition(ds["k"])
    return enumerate_candidates(wl.graph, "d")[0]


@given(st.integers(2, 12),
       st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=300),
       st.sampled_from(["hash", "rr", "random"]))
@settings(max_examples=30, deadline=None)
def test_write_preserves_rows(m, keys, strategy):
    keys = np.array(keys, np.int64)
    vals = np.arange(len(keys), dtype=np.float32)
    store = PartitionStore(num_workers=m)
    if strategy == "hash":
        cand = _keyed_candidate()
    else:
        cand = PartitionerCandidate(
            graph=None,
            strategy=ROUND_ROBIN if strategy == "rr" else RANDOM)
    ds = store.write("d", {"k": keys, "v": vals}, cand)

    assert int(ds.counts.sum()) == len(keys)
    assert ds.capacity == int(ds.counts.max()) if len(keys) else True
    flat = ds.gather()
    # multiset of rows preserved
    got = sorted(zip(flat["k"].tolist(), flat["v"].tolist()))
    want = sorted(zip(keys.tolist(), vals.tolist()))
    assert got == want


@given(st.integers(2, 8),
       st.lists(st.integers(0, 10 ** 6), min_size=10, max_size=300))
@settings(max_examples=20, deadline=None)
def test_hash_colocation_invariant(m, keys):
    """Same key ⇒ same worker (the co-location guarantee joins rely on)."""
    keys = np.array(keys, np.int64)
    store = PartitionStore(num_workers=m)
    ds = store.write("d", {"k": keys}, _keyed_candidate())
    worker_of = {}
    for w in range(m):
        for key in ds.columns["k"][w, :ds.counts[w]]:
            if key in worker_of:
                assert worker_of[key] == w
            worker_of[key] = w


def test_round_robin_balance():
    store = PartitionStore(num_workers=8)
    ds = store.write("d", {"k": np.arange(800)})
    assert ds.skew() == 1.0          # perfectly balanced
    assert ds.partitioner.strategy == ROUND_ROBIN


# -- device backend (DESIGN §5) ----------------------------------------------

@given(st.integers(2, 12),
       st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=300),
       st.sampled_from(["hash", "rr", "random"]))
@settings(max_examples=20, deadline=None)
def test_device_write_matches_host(m, keys, strategy):
    """Same data + partitioner ⇒ device store layout == host store layout,
    bit for bit (counts, padded buffers, gathered rows)."""
    keys = np.array(keys, np.int64)
    vals = np.arange(len(keys), dtype=np.float32)
    if strategy == "hash":
        cand = _keyed_candidate()
    else:
        cand = PartitionerCandidate(
            graph=None,
            strategy=ROUND_ROBIN if strategy == "rr" else RANDOM)
    data = {"k": keys, "v": vals}
    ds_h = PartitionStore(num_workers=m).write("d", data, cand)
    ds_d = PartitionStore(num_workers=m, backend="device").write(
        "d", data, cand)

    assert ds_d.backend == "device"
    np.testing.assert_array_equal(ds_h.counts, ds_d.counts)
    for k in ds_h.columns:
        np.testing.assert_array_equal(ds_h.columns[k],
                                      np.asarray(ds_d.columns[k]))
    gh, gd = ds_h.gather(), ds_d.gather()
    for k in gh:
        assert gh[k].dtype == gd[k].dtype
        np.testing.assert_array_equal(gh[k], gd[k])
