"""Skew-adaptive partitioning tests (DESIGN §12).

Covers the variable-capacity layout end-to-end: CapacityMap planning and
slot arithmetic, the heavy-hitter sketch, bucketed-vs-uniform scatter
bit-identity (hypothesis sweeps over dtypes/skew/zero-row partitions and
d2d-vs-host), the no-retrace guarantee across skew levels, store-level
padded/valid accounting, SaltedPartitioner semantics, the durable
round-trip of the capacity map, and the Autopilot's salt/rebucket
decisions under injected calibrations.
"""

import numpy as np
import pytest

import repro.data.device_repartition as dr
from repro.api import Session
from repro.core import author_integrator, enumerate_candidates, \
    partitioning_match
from repro.core.partitioner import SaltedPartitioner
from repro.data.capacity import (CapacityMap, bucket_capacity,
                                 plan_capacity_map, valid_slot_index)
from repro.data.partition_store import PartitionStore
from repro.data.skew import HeavyHitterSketch, zipf_keys
from repro.service import (Autopilot, AutopilotConfig, LogicalClock,
                           aggregate_result, drift_tables, q_orderkey)

ORDERKEY_SIG = "scan/attr:orderkey/partition[hash]"


# ---------------------------------------------------------------------------
# CapacityMap: buckets, planning, slot arithmetic
# ---------------------------------------------------------------------------

def test_bucket_capacity_powers_of_two():
    assert bucket_capacity(0) == 0
    assert bucket_capacity(1) == 1
    assert bucket_capacity(2) == 2
    assert bucket_capacity(3) == 4
    assert bucket_capacity(1025) == 2048


def test_capacity_map_from_counts_and_offsets():
    cm = CapacityMap.from_counts(np.array([5, 0, 17, 2]))
    np.testing.assert_array_equal(cm.capacities, [8, 0, 32, 2])
    np.testing.assert_array_equal(cm.offsets, [0, 8, 8, 40])
    assert cm.total_slots == 42
    assert cm.num_partitions == 4
    assert not cm.is_uniform()
    assert cm == CapacityMap.of([8, 0, 32, 2])
    assert cm != CapacityMap.of([8, 0, 32, 4])
    assert (cm == None) is False                       # noqa: E711
    assert hash(cm) == hash(CapacityMap.of([8, 0, 32, 2]))


def test_plan_capacity_map_balanced_stays_uniform():
    # near-balanced counts: bucketing buys < the threshold — stay uniform
    assert plan_capacity_map(np.array([100, 101, 99, 100])) is None
    assert plan_capacity_map(np.zeros(4, np.int64)) is None
    assert plan_capacity_map(np.array([], np.int64)) is None
    # one hot partition: bucketed total beats m × bucket(max) easily
    cm = plan_capacity_map(np.array([1000, 10, 10, 10]))
    assert cm is not None
    assert cm.total_slots < 4 * bucket_capacity(1000) * 0.75


def test_valid_slot_index_orders_rows_worker_major():
    counts = np.array([2, 0, 3])
    offs = np.array([0, 2, 2])        # packed buckets (cap == count here)
    np.testing.assert_array_equal(valid_slot_index(counts, offs),
                                  [0, 1, 2, 3, 4])
    uni = np.array([0, 4, 8])         # uniform capacity 4
    np.testing.assert_array_equal(valid_slot_index(counts, uni),
                                  [0, 1, 8, 9, 10])
    assert valid_slot_index(np.zeros(3, np.int64), uni).size == 0


# ---------------------------------------------------------------------------
# Heavy-hitter sketch + zipf generator
# ---------------------------------------------------------------------------

def test_sketch_finds_guaranteed_heavy_hitter():
    rng = np.random.default_rng(0)
    keys = np.concatenate([np.full(600, 7), rng.integers(100, 10_000, 400)])
    rng.shuffle(keys)
    sk = HeavyHitterSketch(k=4).update(keys)
    # freq 0.6 > n/(k+1): guaranteed among the counters, lower-bounded
    hits = dict(sk.heavy_hitters(0.25))
    assert 7 in hits
    assert sk.max_fraction() <= 0.6 + 1e-9     # never overestimates
    assert sk.max_fraction() >= 0.6 - 1.0 / (sk.k + 1)


def test_sketch_batched_updates_and_empty():
    sk = HeavyHitterSketch(k=2)
    assert sk.max_fraction() == 0.0 and sk.heavy_hitters(0.1) == []
    for _ in range(5):
        sk.update([1, 1, 1, 2, 3])
    assert max(sk.counters(), key=sk.counters().get) == 1
    with pytest.raises(ValueError):
        HeavyHitterSketch(k=0)


def test_zipf_keys_deterministic_and_bounded():
    a = zipf_keys(1000, 50, 1.2, seed=3)
    b = zipf_keys(1000, 50, 1.2, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int64
    assert a.min() >= 0 and a.max() < 50
    # skewed: the hottest key dominates a uniform draw's share
    frac = np.bincount(a).max() / 1000
    assert frac > 5 * (1 / 50)


# ---------------------------------------------------------------------------
# Bucketed scatter: bit-identical to the uniform layout (deterministic
# sweeps; the hypothesis generalization lives in test_skew_properties.py)
# ---------------------------------------------------------------------------

PAYLOAD_DTYPES = (np.float32, np.int32, np.float64, np.int64)


@pytest.mark.parametrize("m,dom", [(2, 0), (5, 1), (16, 2), (9, 3)])
def test_bucketed_scatter_rows_equal_uniform(m, dom):
    rng = np.random.default_rng(dom)
    n = 257
    keys = rng.integers(0, 2 ** 31 - 1, n) % (4 ** dom + 1)
    data = {"k": keys,
            "v": (np.arange(n) * 3).astype(PAYLOAD_DTYPES[dom]),
            "mat": np.arange(2 * n, dtype=np.float32).reshape(n, 2)}
    pids_d, hist = dr.device_partition_ids(keys, m)
    counts = np.asarray(hist).astype(np.int64)
    cmap = CapacityMap.from_counts(counts)     # force bucketing (zero-cap
                                               # partitions included)
    uni = dr.device_scatter_padded(data, pids_d, counts)
    buck = dr.device_scatter_padded(data, pids_d, counts, capacity_map=cmap)
    cap = int(counts.max())
    uni_off = np.arange(m, dtype=np.int64) * cap
    vidx_u = valid_slot_index(counts, uni_off)
    vidx_b = valid_slot_index(counts, cmap.offsets)
    for k, v in data.items():
        got_u = np.asarray(uni[k]).reshape((m * cap,) + v.shape[1:])[vidx_u]
        got_b = np.asarray(buck[k])[vidx_b]
        assert got_b.dtype == v.dtype, k
        np.testing.assert_array_equal(got_u, got_b, err_msg=k)


@pytest.mark.parametrize("alpha", [1.05, 1.3, 2.5])
@pytest.mark.parametrize("device", [False, True])
def test_adaptive_store_gather_equals_uniform_store(alpha, device):
    """Store-level bit-identity: the same keyed write through an adaptive
    store (capacity map allowed) and a plain store (always uniform) must
    gather back identical flat rows — host path and d2d path both."""
    m, n = 8, 300
    keys = zipf_keys(n, n, alpha, seed=7)
    cols = {"author": keys,
            "v64": np.arange(n, dtype=np.int64),     # hybrid 64-bit path
            "v32": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    backend = "device" if device else "host"
    out = {}
    for adaptive in (False, True):
        store = PartitionStore(m, backend=backend,
                               adaptive_capacity=adaptive)
        ds = store.write("submissions", cols, cand)
        out[adaptive] = (ds, ds.gather())
    ds_u, flat_u = out[False]
    ds_a, flat_a = out[True]
    assert ds_u.capacity_map is None
    np.testing.assert_array_equal(ds_u.counts, ds_a.counts)
    for k in flat_u:
        assert flat_a[k].dtype == flat_u[k].dtype, k
        np.testing.assert_array_equal(np.asarray(flat_u[k]),
                                      np.asarray(flat_a[k]), err_msg=k)


def test_d2d_repartition_bucketed_equals_host():
    """Device-to-device repartition with a capacity map matches the host
    gather+rewrite route bit for bit."""
    n, m = 5000, 8
    keys = zipf_keys(n, n, 1.3, seed=1)
    cols = {"author": keys, "v": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    dstore = PartitionStore(m, backend="device", adaptive_capacity=True)
    ds = dstore.write("submissions", cols)             # round-robin
    new, moved = dstore.repartition(ds, cand, name="reparted")
    assert new.capacity_map is not None and moved > 0

    hstore = PartitionStore(m, backend="host", adaptive_capacity=True)
    hds = hstore.write("submissions", cols)
    hnew = hstore.write("reparted", hds.gather(), cand)
    np.testing.assert_array_equal(new.counts, hnew.counts)
    assert hnew.capacity_map == new.capacity_map
    fd, fh = new.gather(), hnew.gather()
    for k in fh:
        np.testing.assert_array_equal(np.asarray(fd[k]), np.asarray(fh[k]),
                                      err_msg=k)


def test_skew_levels_share_one_scatter_trace():
    """The no-retrace regression: capacity buckets ride the plan as a
    traced offsets array, so changing skew (new CapacityMap, same shape
    buckets) never re-traces the fused scatter."""
    n, m = 4096, 8
    rng = np.random.default_rng(0)
    data = {"v": rng.normal(size=n).astype(np.float32)}
    dr.clear_plan_cache()
    dr.reset_plan_cache_stats()
    try:
        traces = []
        for alpha in (1.1, 1.5, 2.5):
            keys = zipf_keys(n, n, alpha, seed=2)
            pids_d, hist = dr.device_partition_ids(keys, m)
            counts = np.asarray(hist).astype(np.int64)
            cmap = CapacityMap.from_counts(counts)
            dr.device_scatter_padded(data, pids_d, counts, capacity_map=cmap,
                                     mode="fused")
            traces.append(dr.plan_cache_stats()["traces"])
        assert traces[1] == traces[0] and traces[2] == traces[0], traces
    finally:
        dr.clear_plan_cache()
        dr.reset_plan_cache_stats()


# ---------------------------------------------------------------------------
# StoredDataset.skew() + padded/valid accounting in the write log
# ---------------------------------------------------------------------------

def test_skew_and_padding_accounting():
    n, m = 4000, 8
    keys = zipf_keys(n, n, 2.5, seed=0)
    cols = {"author": keys, "v": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    store = PartitionStore(m)                      # uniform capacities
    ds = store.write("submissions", cols, cand)
    assert ds.skew() > 2.0
    assert ds.padded_bytes > ds.valid_bytes > 0
    assert ds.padding_waste() == ds.padded_bytes - ds.valid_bytes
    stats = store.write_stats()
    assert stats["padded_bytes"] >= ds.padded_bytes
    assert stats["valid_bytes"] >= ds.valid_bytes
    assert stats["max_skew"] >= ds.skew() - 1e-9

    rr = store.write("balanced", {"v": np.arange(n, dtype=np.float32)})
    assert rr.skew() == pytest.approx(1.0, abs=0.01)


def test_rebucket_is_local_nondestructive_and_idempotent():
    n, m = 4000, 8
    keys = zipf_keys(n, n, 2.5, seed=0)
    cols = {"author": keys, "v": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    store = PartitionStore(m)
    ds = store.write("submissions", cols, cand)
    flat = ds.gather()
    gen0 = ds.generation

    new, moved = store.rebucket("submissions")
    assert moved == 0
    assert new.capacity_map is not None
    assert new.generation > gen0
    assert new.partitioner is ds.partitioner        # elisions preserved
    assert new.padded_bytes < ds.padded_bytes
    for k in flat:
        np.testing.assert_array_equal(new.gather()[k], flat[k], err_msg=k)
    assert store.write_log[-1]["path"] == "rebucket"

    again, moved2 = store.rebucket("submissions")   # planned == current
    assert moved2 == 0 and again.generation == new.generation


def test_durable_roundtrip_preserves_capacity_map(tmp_path):
    n, m = 3000, 8
    keys = zipf_keys(n, n, 1.5, seed=4)
    cols = {"author": keys, "v": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    root = str(tmp_path / "store")
    store = PartitionStore(m, root=root, adaptive_capacity=True)
    ds = store.write("submissions", cols, cand)
    assert ds.capacity_map is not None
    flat = ds.gather()

    re = PartitionStore(m, root=root)              # reattach from disk
    ds2 = re.read("submissions")
    assert ds2.capacity_map == ds.capacity_map
    np.testing.assert_array_equal(ds2.counts, ds.counts)
    for k in flat:
        np.testing.assert_array_equal(np.asarray(ds2.gather()[k]), flat[k],
                                      err_msg=k)


# ---------------------------------------------------------------------------
# SaltedPartitioner
# ---------------------------------------------------------------------------

def test_salted_partitioner_spreads_hot_keys_only():
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    salted = SaltedPartitioner(
        graph=cand.graph, strategy=cand.strategy,
        source_dataset=cand.source_dataset, origin=cand.origin,
        hot_keys=(7,), salt_factor=4)
    m = 8
    keys = np.array([7] * 100 + [3] * 10 + [11] * 10)
    data = {"author": keys}
    pids = salted.partition_ids(data, m)
    base = cand.partition_ids(data, m)
    hot = keys == 7
    # cold rows: identical to the plain hash layout
    np.testing.assert_array_equal(pids[~hot], np.asarray(base)[~hot])
    # hot rows: sprayed across exactly salt_factor partitions
    assert len(np.unique(pids[hot])) == 4
    # the salted signature never matches a consumer (Alg. 4): consumers
    # re-shuffle, which is what makes salting correctness-free
    assert "salt4[7]" in salted.signature()
    res = partitioning_match(salted, "submissions",
                             author_integrator().graph)
    assert not res.partition_nodes
    assert salted.kernel_dispatchable is False


def test_salted_store_write_bit_identical():
    n, m = 2000, 8
    keys = zipf_keys(n, n, 2.5, seed=0)
    cols = {"author": keys, "v": np.arange(n, dtype=np.float32)}
    cand = enumerate_candidates(author_integrator().graph, "submissions")[0]
    hot = int(np.bincount(keys).argmax())
    salted = SaltedPartitioner(
        graph=cand.graph, strategy=cand.strategy,
        source_dataset=cand.source_dataset, origin=cand.origin,
        hot_keys=(hot,), salt_factor=4)
    for backend in ("host", "device"):
        plain = PartitionStore(m, backend=backend).write(
            "submissions", cols, cand)
        forked = PartitionStore(m, backend=backend).write(
            "submissions", cols, salted)
        assert forked.skew() < plain.skew()
        a = {k: np.sort(np.asarray(v).reshape(v.shape[0], -1), axis=0)
             for k, v in plain.gather().items()}
        b = {k: np.sort(np.asarray(v).reshape(v.shape[0], -1), axis=0)
             for k, v in forked.gather().items()}
        for k in a:     # same multiset of rows, different placement
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------------
# Autopilot skew actions: hot-key salting + capacity rebucketing
# ---------------------------------------------------------------------------

def _skewed_session(skew=1.5, **cfg_kw):
    tables = drift_tables(n_lineitem=4000, skew=skew)
    store = PartitionStore(num_workers=8)
    for name, data in tables.items():
        store.write(name, data)
    sess = Session(store)
    cfg = AutopilotConfig(min_runs=2.0, hysteresis=0.5, cooldown_ticks=0,
                          skew_actions=True, **cfg_kw)
    ap = Autopilot(sess, clock=LogicalClock(), config=cfg)
    return store, sess, ap


def test_autopilot_salts_hot_key_dataset():
    store, sess, ap = _skewed_session()
    wl = q_orderkey()
    for _ in range(3):
        sess.run(wl)
    vals0, _ = sess.run(wl)
    ref = aggregate_result(vals0, wl)
    # injected calibrations: fast network (repartitions are cheap), slow
    # storage (padding waste is expensive) — the skew-action sweet spot
    ap.cost_model.observe_shuffle(1e9, 0.1)
    ap.cost_model.observe_io(1e6, 1.0)

    rep1 = ap.tick()              # classic keyed repartition lands first
    assert ("lineitem", "repartition") in {(a.dataset, a.kind)
                                           for a in rep1.applied}
    ds = store.read("lineitem")
    assert ds.partitioner.signature() == ORDERKEY_SIG
    assert ds.skew() >= 2.0       # zipf orderkeys under the hash layout
    waste = ds.padding_waste()

    rep2 = ap.tick()              # skew phase: hot-key split
    applied = {(a.dataset, a.kind) for a in rep2.applied}
    assert ("lineitem", "salt") in applied
    a = next(x for x in rep2.applied if x.kind == "salt")
    assert a.decision is not None
    assert "salt" in a.decision.candidate.signature()
    assert a.decision.candidate.hot_keys     # sketched at apply time
    ds2 = store.read("lineitem")
    assert "salt" in ds2.partitioner.signature()
    assert ds2.skew() < ds.skew()
    assert ds2.padding_waste() < waste
    # correctness: salted layouts never match, consumers re-shuffle —
    # results stay bit-identical
    vals, stats = sess.run(wl)
    assert stats.shuffles_performed >= 1
    got = aggregate_result(vals, wl)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
    # no flip-flop: the next tick does not salt again
    rep3 = ap.tick()
    assert ("lineitem", "salt") not in {(x.dataset, x.kind)
                                        for x in rep3.applied}


def test_autopilot_rebuckets_skewed_layout():
    # hot_key_fraction > 1 disables salting: the fallback action must be
    # a local rebucket under a fresh capacity map
    store, sess, ap = _skewed_session(hot_key_fraction=2.0)
    wl = q_orderkey()
    for _ in range(2):
        sess.run(wl)
    vals0, _ = sess.run(wl)
    ref = aggregate_result(vals0, wl)
    ap.cost_model.observe_shuffle(1e9, 0.1)
    ap.cost_model.observe_io(1e6, 1.0)

    ap.tick()                      # keyed repartition (uniform capacity)
    ds = store.read("lineitem")
    assert ds.capacity_map is None and ds.padding_waste() > 0
    gen = ds.generation

    rep2 = ap.tick()
    a = next(x for x in rep2.applied
             if x.dataset == "lineitem" and x.kind == "rebucket")
    assert a.decision is None and a.moved_bytes == 0
    assert a.path == "rebucket"
    assert a.score.padding_benefit_s > 0
    ds2 = store.read("lineitem")
    assert ds2.capacity_map is not None
    assert ds2.generation > gen
    assert ds2.padded_bytes < ds.padded_bytes
    assert ds2.partitioner.signature() == ORDERKEY_SIG   # layout survives
    # the generation flip invalidated cached plans; elisions still hold
    vals, stats = sess.run(wl)
    assert stats.shuffles_elided >= 1
    got = aggregate_result(vals, wl)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
    # idempotent: planned map == current map ⇒ no third action
    rep3 = ap.tick()
    assert ("lineitem", "rebucket") not in {(x.dataset, x.kind)
                                            for x in rep3.applied}


def test_skew_actions_default_follows_store_flag():
    tables = drift_tables(n_lineitem=2000, skew=1.5)
    store = PartitionStore(num_workers=8)          # adaptive_capacity=False
    for name, data in tables.items():
        store.write(name, data)
    sess = Session(store)
    ap = Autopilot(sess, clock=LogicalClock(),
                   config=AutopilotConfig(min_runs=2.0, hysteresis=0.5,
                                          cooldown_ticks=0))
    for _ in range(3):
        sess.run(q_orderkey())
    ap.cost_model.observe_shuffle(1e9, 0.1)
    ap.cost_model.observe_io(1e6, 1.0)
    ap.tick()
    rep2 = ap.tick()
    # skew_actions=None + non-adaptive store ⇒ no salt/rebucket ever
    assert all(a.kind == "repartition" for a in rep2.applied)


def test_autopilot_unsalts_cooled_hot_key():
    """Salt → cool → unsalt round-trips bit-identically (PR 7 leftover).

    While the key is hot, nothing may unwind the split — the skew phase
    owns salted layouts and its hot_key_cooled gate holds.  Once the
    observed hot-key share drops below the unsalt threshold (default
    hot_key_fraction/2), the plain keyed layout comes back, consumers
    elide again, and results match the salted era bit-for-bit."""
    store, sess, ap = _skewed_session(window_s=6.0)
    wl = q_orderkey()
    for _ in range(3):
        sess.run(wl)
    ap.cost_model.observe_shuffle(1e9, 0.1)
    ap.cost_model.observe_io(1e6, 1.0)
    ap.tick()                               # keyed repartition
    ap.tick()                               # hot-key salt
    assert "salt" in store.read("lineitem").partitioner.signature()

    # still hot: a fat repartition calibration makes unwinding cheap, but
    # the hot_key_cooled gate must keep the split in place (no flip-flop)
    ap.cost_model.observe_repartition(1e9, 0.1)
    rep_hot = ap.tick()
    assert not any(a.kind in ("unsalt", "repartition") and
                   a.dataset == "lineitem" for a in rep_hot.applied)
    assert "salt" in store.read("lineitem").partitioner.signature()
    w = next(r for r in rep_hot.why
             if r["dataset"] == "lineitem" and r["action"] == "unsalt")
    assert not w["accepted"]
    assert not next(g for g in w["gates"]
                    if g["gate"] == "hot_key_cooled")["passed"]

    # the key cools: same schema, uniform orderkeys, salted layout kept
    cooled = drift_tables(n_lineitem=4000, skew=0.0, seed=1)
    store.write("lineitem", cooled["lineitem"],
                partitioner=store.read("lineitem").partitioner)
    ref_vals, ref_stats = sess.run(wl)      # salted era: shuffles paid
    assert ref_stats.shuffles_performed >= 1
    ref = aggregate_result(ref_vals, wl)
    for _ in range(6):                      # hot records age out of window
        sess.run(wl)

    rep = ap.tick()
    a = next(x for x in rep.applied if x.kind == "unsalt")
    assert a.dataset == "lineitem" and a.decision is not None
    assert "salt" not in a.decision.candidate.signature()
    ds = store.read("lineitem")
    assert ds.partitioner.signature() == ORDERKEY_SIG
    w = next(r for r in rep.why
             if r["dataset"] == "lineitem" and r["action"] == "unsalt")
    assert w["accepted"]
    assert next(g for g in w["gates"]
                if g["gate"] == "hot_key_cooled")["passed"]

    # round trip: the keyed layout matches Alg. 4 again and the results
    # are bit-identical to the salted era
    vals, stats = sess.run(wl)
    assert stats.shuffles_elided >= 1
    got = aggregate_result(vals, wl)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
    # stable: the next tick neither re-salts nor re-unsalts
    rep2 = ap.tick()
    assert not any(x.kind in ("salt", "unsalt") for x in rep2.applied)
