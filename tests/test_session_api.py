"""Session API tests (DESIGN §9): planner/executor split, plan cache,
backend registry, explain() golden output, and the legacy Engine shim.

Covers the ISSUE 4 acceptance criteria: Session parity with legacy
``Engine.run`` (bit-identical host/device), pure plan-cache hits on
re-runs of an unchanged workload (0 new traces), layout-generation flips
invalidating exactly the affected plans, deterministic ``explain``, the
``UnknownBackendError`` bugfix (both entry-point spellings), and the
gated per-candidate measurement pass.
"""

import warnings

import numpy as np
import pytest

import lachesis
from repro.api import RunResult, Session
from repro.core import (Engine, UnknownBackendError, author_integrator,
                        enumerate_candidates, pagerank_iteration)
from repro.core.backends import REGISTRY, Backend, BackendRegistry
from repro.core.executor import StalePlanError, TableVal
from repro.data.device_repartition import default_mode
from repro.data.partition_store import PartitionStore


# -- fixtures ----------------------------------------------------------------

def _reddit_data(n_sub=3000, n_auth=500, seed=0):
    rng = np.random.default_rng(seed)
    subs = {"author": rng.integers(0, n_auth, n_sub).astype(np.int64),
            "score": rng.normal(size=n_sub).astype(np.float32)}
    auths = {"author": np.arange(n_auth, dtype=np.int64),
             "karma": rng.normal(size=n_auth).astype(np.float32)}
    return subs, auths


def _seeded_store(partitioned: bool, backend: str = "host", m: int = 8):
    wl = author_integrator()
    subs, auths = _reddit_data()
    store = PartitionStore(num_workers=m, backend=backend)
    if partitioned:
        store.write("submissions", subs,
                    enumerate_candidates(wl.graph, "submissions")[0])
        store.write("authors", auths,
                    enumerate_candidates(wl.graph, "authors")[0])
    else:
        store.write("submissions", subs)
        store.write("authors", auths)
    return wl, store


def _assert_same_values(va, vb):
    assert set(va) == set(vb)
    for nid in va:
        a, b = va[nid], vb[nid]
        if isinstance(a, TableVal):
            np.testing.assert_array_equal(a.counts, b.counts)
            assert set(a.columns) == set(b.columns)
            for k in a.columns:
                x, y = np.asarray(a.columns[k]), np.asarray(b.columns[k])
                assert x.dtype == y.dtype, (nid, k)
                np.testing.assert_array_equal(x, y)


# -- parity with the legacy Engine -------------------------------------------

@pytest.mark.parametrize("partitioned", [False, True])
def test_session_parity_with_engine(partitioned):
    wl, store = _seeded_store(partitioned)
    res = Session(store).run(wl)
    assert isinstance(res, RunResult)

    wl2, store2 = _seeded_store(partitioned)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        vals, stats = Engine(store2).run(wl2)
    _assert_same_values(res.values, vals)
    assert res.stats.shuffles_performed == stats.shuffles_performed
    assert res.stats.shuffles_elided == stats.shuffles_elided
    assert res.stats.shuffle_bytes == stats.shuffle_bytes


def test_session_parity_host_device():
    wl_h, host = _seeded_store(False, backend="host")
    wl_d, dev = _seeded_store(False, backend="device")
    res_h = Session(host, backend="host").run(wl_h)
    res_d = Session(dev, backend="device").run(wl_d)
    _assert_same_values(res_h.values, res_d.values)
    assert res_d.stats.device_repartitions == \
        res_d.stats.shuffles_performed == 2
    assert res_h.stats.device_repartitions == 0


def test_session_pagerank_matches_engine():
    """A write-back workload (pagerank writes the ranks it scans): every
    run flips the layout generation, so each run re-plans — and results
    stay identical to the legacy path."""
    def build():
        n, fanout = 600, 4
        rng = np.random.default_rng(1)
        neighbors = rng.integers(0, n, (n, fanout)).astype(np.int64)
        pages = {"url": np.arange(n, dtype=np.int64), "neighbors": neighbors}
        ranks = {"url": np.arange(n, dtype=np.int64),
                 "rank": np.full(n, 1.0 / n, np.float64)}
        wl = pagerank_iteration()

        def emit(cols):
            contrib = np.repeat((cols["rank"] / fanout)[:, None], fanout, 1)
            return {"url": cols["neighbors"], "contrib": contrib}
        for node in wl.graph.nodes.values():
            if node.params.get("tag") == "emit_contribs":
                node.params["fn"] = emit
        store = PartitionStore(num_workers=4)
        store.write("pages", pages,
                    enumerate_candidates(wl.graph, "pages")[0])
        store.write("ranks", ranks,
                    enumerate_candidates(wl.graph, "ranks")[0])
        return wl, store

    wl, store = build()
    sess = Session(store)
    r1 = sess.run(wl)
    assert r1.stats.plan_cache_hit is False
    assert r1.stats.shuffles_elided >= 2        # co-partitioned on url
    # the run's own write flipped the ranks generation: the cached plan is
    # stale, the next plan lookup is a miss (exact invalidation)
    _plan, hit = sess.planner.physical(wl, "host")
    assert hit is False

    wl2, store2 = build()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        v1, _ = Engine(store2).run(wl2)
    _assert_same_values(r1.values, v1)


# -- plan cache ---------------------------------------------------------------

def test_plan_cache_hit_and_exact_generation_invalidation():
    wl, store = _seeded_store(True)
    subs, _auths = _reddit_data()
    sess = Session(store)

    r1 = sess.run(wl)
    assert r1.stats.plan_cache_hit is False
    r2 = sess.run(wl)
    assert r2.stats.plan_cache_hit is True
    _assert_same_values(r1.values, r2.values)

    # a second workload scanning a *different* dataset
    other = lachesis.Workload("other")
    o = other.scan("other_ds")
    other.aggregate(o, key=o["k"], reducer="sum")
    store.write("other_ds", {"k": np.arange(50) % 5,
                             "v": np.ones(50, np.float64)})
    assert sess.run(other).stats.plan_cache_hit is False
    assert sess.run(other).stats.plan_cache_hit is True

    # flip submissions' layout generation: the author workload must
    # re-plan, the other workload's plan must stay cached
    store.write("submissions", subs)            # round-robin now, gen+1
    r3 = sess.run(wl)
    assert r3.stats.plan_cache_hit is False
    assert r3.stats.shuffles_performed > r2.stats.shuffles_performed
    assert sess.run(other).stats.plan_cache_hit is True

    st = sess.plan_cache_stats()
    assert st["misses"] == 3 and st["hits"] == 3 and st["size"] == 3


def test_plan_cache_no_retrace_on_device_reruns():
    wl, store = _seeded_store(False, backend="device")
    sess = Session(store, backend="device")
    sess.run(wl)                                # traces the shuffle plans
    base = sess.plan_cache_stats()["traces"]
    for _ in range(3):
        res = sess.run(wl)
        assert res.stats.plan_cache_hit is True
    assert sess.plan_cache_stats()["traces"] == base


def test_stale_plan_rejected():
    wl, store = _seeded_store(True)
    sess = Session(store)
    plan = sess.plan(wl)
    subs, _ = _reddit_data()
    store.write("submissions", subs)            # generation flip
    with pytest.raises(StalePlanError):
        sess.executor.execute(plan)
    # but Session.run re-plans transparently
    assert sess.run(wl).stats.plan_cache_hit is False


def test_run_replans_transparently_on_race(monkeypatch):
    """A layout swap landing between the plan-cache lookup and execution
    (background Autopilot) must trigger a silent re-plan, not an error."""
    wl, store = _seeded_store(True)
    subs, _ = _reddit_data()
    sess = Session(store)
    stale_plan = sess.plan(wl)                  # pins submissions@gen0
    ref = sess.run(wl)

    store.write("submissions", subs,
                enumerate_candidates(wl.graph, "submissions")[0])  # gen1
    real_physical = sess.planner.physical
    raced = {"n": 0}

    def physical_racing(workload, backend):
        if raced["n"] == 0:                     # first lookup: the race —
            raced["n"] += 1                     # hand back the stale plan
            return stale_plan, True
        return real_physical(workload, backend)

    monkeypatch.setattr(sess.planner, "physical", physical_racing)
    res = sess.run(wl)                          # no StalePlanError escapes
    assert raced["n"] == 1                      # retry went through re-plan
    assert res.plan is not stale_plan
    assert res.plan.key.layout != stale_plan.key.layout
    _assert_same_values(res.values, ref.values)  # same partitioner ⇒ same rows


def test_failed_run_keeps_implicit_workload():
    _, store = _seeded_store(True)
    sess = Session(store)
    subs = sess.scan("submissions")
    auths = sess.scan("authors")
    j = sess.join(subs, auths,
                  left_key=subs.parse("json")["author"],
                  right_key=auths.parse("csv")["author"],
                  tag="author_join")
    sess.write_result(j, "integrated")
    with pytest.raises(UnknownBackendError):
        sess.run(backend="devcie")
    assert sess.current is not None             # not lost by the failure
    res = sess.run()                            # retry succeeds and clears
    assert sess.current is None
    assert res.stats.shuffles_elided == 2


def test_invalidate_and_lru_bound():
    wl, store = _seeded_store(True)
    sess = Session(store, plan_cache_capacity=1)
    sess.run(wl)
    assert sess.plan_cache_stats()["size"] == 1
    assert sess.invalidate("submissions") == 1
    assert sess.plan_cache_stats()["size"] == 0
    sess.run(wl)
    sess.run(wl, backend="device")              # evicts the host plan
    st = sess.plan_cache_stats()
    assert st["size"] == 1 and st["evictions"] == 1


# -- explain ------------------------------------------------------------------

def _golden_store(backend="host"):
    wl = author_integrator()
    subs = {"author": np.arange(100, dtype=np.int64) % 20,
            "score": np.ones(100, np.float32)}
    auths = {"author": np.arange(20, dtype=np.int64),
             "karma": np.ones(20, np.float32)}
    sess = Session(num_workers=4, backend=backend)
    sess.write("submissions", subs,
               enumerate_candidates(wl.graph, "submissions")[0])
    sess.write("authors", auths)
    return wl, sess


GOLDEN_HOST_EXPLAIN = """\
PhysicalPlan author-integrator backend=host workers=4 matching=on
  ir: 26f88a8d53ad
  layout: authors@gen0[roundrobin] submissions@gen0[scan/parse:json/attr:author/partition[hash]]
  steps:
    [  0] scan submissions rows=100 gen=0
    [  1] scan authors rows=20 gen=0
    [  2] parse:json
    [  3] attr:author
    [  4] parse:csv
    [  5] attr:author
    [  6] partition[hash] key<-n3 src=submissions ELIDED (Alg.4 static: layout matches scan/parse:json/attr:author/partition[hash])
    [  7] partition[hash] key<-n5 src=authors op=host_argsort bucket=dynamic shuffle
    [  8] join
    [  9] write integrated
  shuffles: elided=1 performed=1"""


def test_explain_golden_and_deterministic():
    wl, sess = _golden_store()
    assert sess.explain(wl) == GOLDEN_HOST_EXPLAIN
    # deterministic: a fresh identical session + freshly traced workload
    # produces the identical dump, and repeated calls are stable
    wl2, sess2 = _golden_store()
    assert sess2.explain(wl2) == sess.explain(wl)


def test_explain_device_shows_op_and_bucket():
    wl = author_integrator()
    sess = Session(num_workers=4, backend="device")
    sess.write("submissions", {"author": np.arange(100, dtype=np.int64) % 20,
                               "score": np.ones(100, np.float32)})
    sess.write("authors", {"author": np.arange(20, dtype=np.int64),
                           "karma": np.ones(20, np.float32)})
    txt = sess.explain(wl)
    mode = default_mode()
    # per partition node: bound backend op + static ShufflePlan bucket
    assert f"op=device_rebucket[{mode}] bucket=B128 shuffle" in txt
    assert f"op=device_rebucket[{mode}] bucket=B32 shuffle" in txt
    assert txt == sess.explain(wl)              # deterministic
    # and the elided case still renders under the device backend
    wl2, sess2 = _golden_store(backend="device")
    assert "ELIDED (Alg.4 static" in sess2.explain(wl2)


# -- backend registry (ISSUE 4 satellite bugfix) ------------------------------

@pytest.mark.parametrize("bad", ["devise", "Device", "gpu", ""])
def test_unknown_backend_all_entry_points(bad):
    wl, store = _seeded_store(False)
    for ctor in (lambda: Session(store, backend=bad),
                 lambda: PartitionStore(backend=bad),
                 lambda: Engine(store, backend=bad),
                 lambda: Session(store).run(wl, backend=bad),
                 lambda: Session(store).plan(wl, backend=bad)):
        with pytest.raises(UnknownBackendError) as ei:
            ctor()
        msg = str(ei.value)
        assert repr(bad) in msg
        assert "host" in msg and "device" in msg    # lists what IS registered
    # both historical failure spellings remain catchable
    assert issubclass(UnknownBackendError, KeyError)
    assert issubclass(UnknownBackendError, ValueError)


def test_engine_run_backend_override_validated():
    wl, store = _seeded_store(False)
    eng = Engine(store)
    with pytest.raises(UnknownBackendError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng.run(wl, backend="dvice")


def test_matching_toggle_forwards_to_planner():
    """The pre-split `eng.matching = False` idiom must keep disabling
    Alg. 4 elision (the knob lives in the Planner now)."""
    wl, store = _seeded_store(True)
    sess = Session(store)
    assert sess.run(wl).stats.shuffles_elided == 2
    sess.matching = False
    st = sess.run(wl).stats
    assert st.shuffles_elided == 0 and st.shuffles_performed == 2
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = Engine(store)
        eng.matching = False
        _, est = eng.run(wl)
    assert est.shuffles_elided == 0 and est.shuffles_performed == 2


def test_custom_device_resident_backend_stores_on_device():
    """A registered backend with device_resident=True must get
    device-resident columns — capability, not the literal name — and the
    session's own ``registry=`` must reach the store it creates."""
    import jax
    reg = BackendRegistry()
    reg.register(Backend("host"))
    reg.register(Backend("device", device_resident=True,
                         kernel_shuffle=True, device_relay=True))
    reg.register(Backend("mydev", device_resident=True,
                         kernel_shuffle=True, device_relay=True))
    store = PartitionStore(num_workers=4, backend="mydev", registry=reg)
    ds = store.write("t", {"k": np.arange(64, dtype=np.int32)})
    assert any(isinstance(v, jax.Array) for v in ds.columns.values())
    assert ds.backend == "device"               # columns live on device

    # end-to-end through a Session with its own registry
    sess = Session(num_workers=4, backend="mydev", registry=reg)
    subs, auths = _reddit_data(400, 80)
    sess.write("submissions", subs)
    sess.write("authors", auths)
    wl = author_integrator()
    res = sess.run(wl)
    assert res.stats.device_repartitions == res.stats.shuffles_performed == 2
    host = Session(num_workers=4)               # host oracle, bit-identical
    host.write("submissions", subs)
    host.write("authors", auths)
    _assert_same_values(res.values, host.run(wl).values)


def test_param_twins_do_not_share_plans():
    """Two structurally identical workloads with different UDFs / write
    targets must not collide in the plan cache (the IR signature is
    structural by design; the param fingerprint disambiguates)."""
    store = PartitionStore(num_workers=4)
    store.write("t", {"v": np.arange(32, dtype=np.float64)})
    sess = Session(store)

    def make(mult, out):
        wl = lachesis.Workload(f"x{mult}")
        s = wl.scan("t")
        m = wl.map(s, fn=lambda c, _k=mult: {"v": c["v"] * _k}, tag="scale")
        wl.write(m, out)
        return wl

    wl2, wl100 = make(2, "out2"), make(100, "out100")
    assert wl2.graph.graph_signature() == wl100.graph.graph_signature()
    r2 = sess.run(wl2)
    r100 = sess.run(wl100)
    assert r100.stats.plan_cache_hit is False   # no silent collision
    np.testing.assert_array_equal(               # worker-segment order
        np.sort(store.read("out2").gather()["v"]), np.arange(32) * 2.0)
    np.testing.assert_array_equal(               # wl100's fn + target ran
        np.sort(store.read("out100").gather()["v"]), np.arange(32) * 100.0)
    # same workload object re-runs still hit
    assert sess.run(wl2).stats.plan_cache_hit is True
    # and rebuilt param-free workloads keep hitting across objects
    _, pstore = _seeded_store(True)
    psess = Session(pstore)
    psess.run(author_integrator())
    assert psess.run(author_integrator()).stats.plan_cache_hit is True


def test_registry_capabilities_and_plugging():
    reg = BackendRegistry()
    reg.register(Backend("host"))
    reg.register(Backend("device", device_resident=True,
                         kernel_shuffle=True, device_relay=True))
    assert [b.name for b in reg.with_capability(kernel_shuffle=True)] \
        == ["device"]
    with pytest.raises(ValueError):
        reg.register(Backend("host"))           # no silent overwrite
    assert "host" in REGISTRY and "device" in REGISTRY
    assert REGISTRY.get("device").partition_op("hash").startswith(
        "device_rebucket[")
    assert REGISTRY.get("host").partition_op("hash") == "host_argsort"
    assert REGISTRY.get("host").partition_op("range") == "host_range"


# -- measurement-pass gating (ISSUE 4 satellite bugfix) -----------------------

def test_candidate_measurement_gated_behind_observation(monkeypatch):
    import repro.core.executor as ex
    calls = []
    orig = ex._record_candidate_stats
    monkeypatch.setattr(
        ex, "_record_candidate_stats",
        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])

    wl, store = _seeded_store(False)
    sess = Session(store)
    res = sess.run(wl)                          # unobserved run
    assert calls == []                          # measurement pass skipped
    assert res.stats.candidate_stats is None
    assert res.stats.candidate_measure_passes == 0

    seen = []
    sess.add_run_hook(lambda w, s: seen.append(s))
    res2 = sess.run(wl)                         # observed run
    assert len(calls) == 2                      # one pass per partition node
    assert res2.stats.candidate_measure_passes == 2
    assert res2.stats.candidate_stats           # hooks see measured stats
    assert seen and seen[0] is res2.stats


# -- deprecation shim ---------------------------------------------------------

def test_engine_run_warns_deprecation():
    wl, store = _seeded_store(True)
    eng = Engine(store)
    with pytest.warns(DeprecationWarning, match="Session"):
        vals, stats = eng.run(wl)
    assert stats.shuffles_elided == 2
    # the shim shares the same planner stack: second run is a cache hit
    with pytest.warns(DeprecationWarning):
        _, stats2 = eng.run(wl)
    assert stats2.plan_cache_hit is True


# -- session DSL passthrough --------------------------------------------------

def test_session_implicit_workload_builder():
    _, store = _seeded_store(True)
    sess = Session(store)
    subs = sess.scan("submissions")
    auths = sess.scan("authors")
    j = sess.join(subs, auths,
                  left_key=subs.parse("json")["author"],
                  right_key=auths.parse("csv")["author"],
                  tag="author_join")
    sess.write_result(j, "integrated")
    assert sess.current is not None
    res = sess.run()                            # runs + clears the implicit wl
    assert sess.current is None
    assert res.stats.shuffles_elided == 2       # same IR ⇒ same elisions
    ref = Session(store).run(author_integrator())
    assert res.workload.graph.graph_signature() \
        == ref.workload.graph.graph_signature()
    _assert_same_values(res.values, ref.values)
    with pytest.raises(ValueError, match="no workload"):
        sess.run()


def test_session_autopilot_attach():
    wl, store = _seeded_store(False)
    sess = Session(store)
    ap = sess.autopilot()
    sess.run(wl)
    assert ap.history.total_runs() == 1         # observed automatically
    assert ap.session is sess


def test_observer_no_double_log_with_shared_history():
    """Exactly one ExecutionRecord per run, however the HistoryStore is
    shared (double records would double the run rates the cost model
    prices from) — and runs on a session that does NOT share it must
    still be recorded."""
    from repro.core import HistoryStore
    wl, store = _seeded_store(False)
    h = HistoryStore()
    sess = Session(store, history=h)
    ap = sess.autopilot(history=h)
    sess.run(wl)
    assert h.total_runs() == 1
    assert ap.observer.records_seen == 1
    sess.run(wl)
    assert h.total_runs() == 2

    # per-call history override sharing the observer's store: still one
    sess2 = Session(store)                      # no constructor history
    ap2 = sess2.autopilot()
    sess2.run(wl, history=ap2.history)
    assert ap2.history.total_runs() == 1

    # a second session attached to the same observer WITHOUT sharing the
    # history must not be silently dropped
    _, store3 = _seeded_store(False)
    sess3 = Session(store3)
    ap2.observer.attach(sess3)
    sess3.run(wl)
    assert ap2.history.total_runs() == 2


def test_compile_pins_key_layout_not_live_store():
    """compile(key=...) must resolve datasets at the key's pinned
    generations, so a concurrent swap between key computation and compile
    cannot cache a plan that disagrees with its key."""
    wl, store = _seeded_store(True)
    sess = Session(store)
    key0 = sess.planner.plan_key(wl, "host")
    subs, _ = _reddit_data()
    store.write("submissions", subs)            # live store moves to gen1 rr
    plan = sess.planner.compile(sess.planner.logical(wl), "host", key=key0)
    scan = next(s for s in plan.steps
                if s.kind == "scan" and s.dataset == "submissions")
    assert scan.generation == 0                 # pinned, not live
    # elision was decided against the pinned partitioned gen0 layout,
    # not the live round-robin gen1 one
    assert len(plan.elided) == 2
