"""Deterministic race regression tests (DESIGN §11).

Each test freezes a racing thread at an exact point inside the store —
via the injected sync points (``store.set_sync_point``), i.e. real
``threading.Event`` barriers, not sleeps — and then drives the other
side of the race through the frozen window.  These are regression tests
for the specific interleavings the serving tier makes routine:

* a read racing ``_install``'s generation-pointer flip (both sides of
  the flip instant);
* a ``gather()`` racing spill's per-column RAM→memmap container swap
  (the mixed half-spilled state);
* a read racing prefetch's memmap→RAM page-in promotion;
* plan/execute racing a swap: the executor's up-front generation check
  fails *before* any step runs, and ``plan_and_execute`` re-plans.
"""

import threading

import numpy as np
import pytest

from repro.api import Session
from repro.core.dsl import Workload
from repro.core.executor import StalePlanError
from repro.core.partitioner import enumerate_candidates
from repro.data.partition_store import PartitionStore


def _data(n=2000, seed=0):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, 500, n),
            "v": rng.integers(0, 100, n).astype(np.float64)}


def _candidate():
    wl = Workload("probe")
    x = wl.scan("d")
    wl.aggregate(x, key=x["k"], reducer="sum")
    return enumerate_candidates(wl.graph, "d")[0]


def _canonical(ds):
    flat = ds.gather()
    order = np.lexsort((flat["v"], flat["k"]))
    return {k: np.ascontiguousarray(np.asarray(v)[order])
            for k, v in flat.items()}


def _assert_same(got, want):
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


class _Freeze:
    """Reusable one-shot barrier: the hooked thread parks at the sync
    point (signalling ``reached``) until the test calls ``release()``.
    Subsequent hits pass straight through."""

    def __init__(self):
        self.reached = threading.Event()
        self._go = threading.Event()
        self._armed = True

    def __call__(self):
        if not self._armed:
            return
        self._armed = False
        self.reached.set()
        assert self._go.wait(60), "race test deadlocked at sync point"

    def release(self):
        self._go.set()


# ---------------------------------------------------------------------------
# read vs _install: the generation-pointer flip
# ---------------------------------------------------------------------------

def test_read_racing_install_pre_flip_sees_old_generation():
    store = PartitionStore(num_workers=4, backend="host")
    store.write("d", _data())
    baseline = _canonical(store.read("d"))
    freeze = _Freeze()
    store.set_sync_point("install:pre_flip", freeze)
    try:
        t = threading.Thread(
            target=lambda: store.repartition(store.read("d"), _candidate(),
                                             swap=True))
        t.start()
        assert freeze.reached.wait(60)
        # the writer is parked one instruction before the pointer flip:
        # a read right now MUST resolve generation 0 and stay pinned to it
        reader = store.read("d")
        assert reader.generation == 0
        pre_bits = _canonical(reader)
        freeze.release()
        t.join(60)

        # flip landed; the held object still reads its own bits
        assert store.read("d").generation == 1
        _assert_same(pre_bits, baseline)
        _assert_same(_canonical(reader), baseline)        # post-flip
        assert reader.generation == 0                     # immutable pin
        # the retained generation resolves to the very same object
        assert store.read("d", generation=0) is reader
        _assert_same(_canonical(store.read("d")), baseline)
    finally:
        store.set_sync_point("install:pre_flip", None)


def test_read_racing_install_post_flip_sees_new_generation():
    store = PartitionStore(num_workers=4, backend="host")
    store.write("d", _data())
    baseline = _canonical(store.read("d"))
    freeze = _Freeze()
    store.set_sync_point("install:post_flip", freeze)
    try:
        t = threading.Thread(
            target=lambda: store.repartition(store.read("d"), _candidate(),
                                             swap=True))
        t.start()
        assert freeze.reached.wait(60)
        # the writer is parked one instruction AFTER the flip: the new
        # generation must already be complete and readable — no torn state
        reader = store.read("d")
        assert reader.generation == 1
        _assert_same(_canonical(reader), baseline)
        freeze.release()
        t.join(60)
    finally:
        store.set_sync_point("install:post_flip", None)


def test_pinned_read_is_atomic_across_flip():
    """Regression: ``read(name, generation=G)`` must return the object it
    validated, not re-read the pointer — a flip between the generation
    check and the return used to hand back the wrong generation."""
    store = PartitionStore(num_workers=4, backend="host")
    store.write("d", _data())
    gen0 = store.read("d", generation=0)
    store.repartition(store.read("d"), _candidate(), swap=True)
    assert gen0.generation == 0
    assert store.read("d", generation=0) is gen0
    assert store.read("d", generation=1) is not gen0


# ---------------------------------------------------------------------------
# gather vs spill: the per-column RAM -> memmap container swap
# ---------------------------------------------------------------------------

def test_gather_racing_spill_mid_column_swap(tmp_path):
    store = PartitionStore(num_workers=4, backend="host",
                           root=str(tmp_path / "store"))
    store.write("d", _data())
    store.flush()
    baseline = _canonical(store.read("d"))

    hits = []

    class SecondColumnFreeze(_Freeze):
        # pass through the first column, freeze before the second flips:
        # exactly one column is a memmap view, the other still RAM
        def __call__(self):
            hits.append(1)
            if len(hits) == 2:
                super().__call__()

    freeze = SecondColumnFreeze()
    store.set_sync_point("spill:column", freeze)
    try:
        t = threading.Thread(target=lambda: store.spill("d"))
        t.start()
        assert freeze.reached.wait(60)
        ds = store.read("d")
        kinds = {k: isinstance(v, np.memmap) for k, v in ds.columns.items()}
        assert sorted(kinds.values()) == [False, True], \
            f"expected the frozen half-spilled state, got {kinds}"
        # a reader in the mixed state still gathers bit-identical rows
        _assert_same(_canonical(ds), baseline)
        freeze.release()
        t.join(60)
        assert store.is_spilled("d")
        _assert_same(_canonical(store.read("d")), baseline)
    finally:
        store.set_sync_point("spill:column", None)


def test_gather_racing_prefetch_page_in(tmp_path):
    store = PartitionStore(num_workers=4, backend="host",
                           root=str(tmp_path / "store"))
    store.write("d", _data())
    store.flush()
    assert store.spill("d")
    baseline = _canonical(store.read("d"))

    freeze = _Freeze()
    store.set_sync_point("prefetch:pre_swap", freeze)
    try:
        t = threading.Thread(target=lambda: store.prefetch("d"))
        t.start()
        assert freeze.reached.wait(60)
        # promotion fully staged but not yet swapped in: readers still see
        # the memmap containers and must gather the identical bits
        ds = store.read("d")
        assert ds.spilled
        _assert_same(_canonical(ds), baseline)
        freeze.release()
        t.join(60)
        assert not store.read("d").spilled
        _assert_same(_canonical(store.read("d")), baseline)
    finally:
        store.set_sync_point("prefetch:pre_swap", None)


def test_spill_prefetch_same_name_serialize_without_deadlock(tmp_path):
    """The per-name lock serializes spill and prefetch on one dataset; a
    storm of both from many threads must neither deadlock nor corrupt."""
    store = PartitionStore(num_workers=4, backend="host",
                           root=str(tmp_path / "store"))
    store.write("d", _data())
    store.flush()
    baseline = _canonical(store.read("d"))
    errors = []

    def storm(op):
        try:
            for _ in range(8):
                op("d")
                _assert_same(_canonical(store.read("d")), baseline)
        except BaseException as e:      # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=storm, args=(op,))
               for op in (store.spill, store.prefetch) * 3]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "spill/prefetch deadlock"
    assert not errors, f"storm failed: {errors[:2]}"
    _assert_same(_canonical(store.read("d")), baseline)


# ---------------------------------------------------------------------------
# execute vs swap: the up-front generation check + transparent re-plan
# ---------------------------------------------------------------------------

def test_stale_plan_fails_before_any_step_then_replans():
    sess = Session(num_workers=4)
    sess.write("d", _data())
    wl = Workload("q")
    x = wl.scan("d")
    wl.aggregate(x, key=x["k"], reducer="sum")

    plan, hit = sess.planner.physical(wl, "host")
    assert not hit
    # the layout moves after the plan was cached...
    sess.store.repartition(sess.store.read("d"), _candidate(), swap=True)

    # ...executing the stale plan fails at validation, before any step:
    # no partial values, no partial writes
    with pytest.raises(StalePlanError):
        sess.executor.execute(plan)

    # while the session-level path re-plans transparently
    res = sess.run(wl)
    assert res.stats.shuffles_elided >= 1 or res.stats.shuffles_performed >= 0
    agg_node = max(n for n, nd in wl.graph.nodes.items()
                   if nd.kind == "aggregate")
    assert res.values[agg_node] is not None


def test_install_blocked_at_flip_does_not_block_other_datasets():
    """The frozen writer holds only its own name lock — reads AND writes
    of other datasets proceed while one dataset's flip is parked."""
    store = PartitionStore(num_workers=4, backend="host")
    store.write("d", _data(seed=0))
    store.write("e", _data(seed=1))
    base_e = _canonical(store.read("e"))
    freeze = _Freeze()
    store.set_sync_point("install:pre_flip", freeze)
    try:
        t = threading.Thread(
            target=lambda: store.repartition(store.read("d"), _candidate(),
                                             swap=True))
        t.start()
        assert freeze.reached.wait(60)
        # "e" is fully usable while "d"'s flip is frozen mid-install
        _assert_same(_canonical(store.read("e")), base_e)
        store.set_sync_point("install:pre_flip", None)   # unhook before e
        store.write("e", _data(seed=2))
        assert store.read("e").generation == 1
        freeze.release()
        t.join(60)
        assert store.read("d").generation == 1
    finally:
        store.set_sync_point("install:pre_flip", None)
