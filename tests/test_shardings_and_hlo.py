"""Sharding-rule coverage (AbstractMesh — no devices needed) + HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch import hlo_analysis as H
from repro.launch import shardings, specs
from repro.core.sharding_bridge import specs_match, would_elide_collective


def _mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    names = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:                                  # jax >= 0.5 signature
        return AbstractMesh(shape, names)
    except TypeError:                     # jax 0.4.x: tuple of (name, size)
        return AbstractMesh(tuple(zip(names, shape)))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_cover_and_divide(arch, multi_pod):
    """Every param leaf gets a spec; every sharded dim divides evenly."""
    cfg = get_config(arch)
    mesh = _mesh(multi_pod)
    sizes = dict(mesh.shape)
    struct = specs.params_struct(cfg)
    spec_tree = shardings.param_pspecs(cfg, struct, mesh)
    spec_tree = shardings.shard_over_dp(spec_tree, struct, mesh) \
        if cfg.param_count() >= shardings.FSDP_THRESHOLD else spec_tree

    leaves = jax.tree.leaves(struct)
    spec_leaves = jax.tree.leaves(spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = []
        for dim, e in zip(leaf.shape, entries):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            shard = 1
            for a in axes:
                assert a in sizes, f"{arch}: unknown axis {a}"
                shard *= sizes[a]
                assert a not in used, f"{arch}: axis {a} reused in {spec}"
                used.append(a)
            assert dim % shard == 0, \
                f"{arch}: dim {dim} not divisible by {shard} ({spec})"


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "mamba2-370m",
                                  "deepseek-v2-236b", "whisper-small"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    mesh = _mesh()
    sizes = dict(mesh.shape)
    for B, L in ((128, 32768), (1, 524288)):
        struct = specs.cache_struct(cfg, B, L)
        spec_tree = shardings.cache_pspecs(cfg, struct, B, mesh)
        for leaf, spec in zip(
                jax.tree.leaves(struct),
                jax.tree.leaves(spec_tree,
                                is_leaf=lambda x: isinstance(x, P))):
            entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for dim, e in zip(leaf.shape, entries):
                if e is None:
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                shard = int(np.prod([sizes[a] for a in axes]))
                assert dim % shard == 0, f"{arch} {leaf.shape} {spec}"


def test_zero1_shards_moments_over_dp():
    cfg = get_config("gemma2-27b")
    mesh = _mesh()
    struct = specs.params_struct(cfg)
    base = shardings.param_pspecs(cfg, struct, mesh)
    z = shardings.shard_over_dp(base, struct, mesh)
    base_l = jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P))
    z_l = jax.tree.leaves(z, is_leaf=lambda x: isinstance(x, P))
    more = sum(1 for b, zz in zip(base_l, z_l) if b != zz)
    assert more > 0                       # ZeRO actually sharded something


def test_sharding_bridge_match():
    assert specs_match(P("data", None), P("data"))
    assert not specs_match(P("data", None), P(None, "data"))
    assert would_elide_collective(P("data", None), P("data", None))


# -- HLO analyzer -----------------------------------------------------------------

def test_hlo_analyzer_counts_scan_trips():
    N, G = 256, 8
    A = jax.ShapeDtypeStruct((N, N), jnp.float32)
    W = jax.ShapeDtypeStruct((G, N, N), jnp.float32)

    def f(a, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, a, ws)
        return h

    c = jax.jit(f).lower(A, W).compile()
    t = H.analyze(c.as_text())
    expect = G * 2 * N ** 3
    assert abs(t.flops - expect) / expect < 0.05
    # XLA's own cost analysis counts the body once — our analyzer must not
    ca = c.cost_analysis()
    if isinstance(ca, list):              # jax 0.4.x returns [dict]
        ca = ca[0]
    assert t.flops > (ca.get("flops", 0) or 0) * (G - 1)


def test_hlo_analyzer_nested_scan():
    N, G1, G2 = 128, 3, 4
    A = jax.ShapeDtypeStruct((N, N), jnp.float32)
    W = jax.ShapeDtypeStruct((G2, N, N), jnp.float32)

    def f(a, ws):
        def outer(h, _):
            def inner(hh, w):
                return jnp.tanh(hh @ w), None
            h2, _ = jax.lax.scan(inner, h, ws)
            return h2, None
        h, _ = jax.lax.scan(outer, a, None, length=G1)
        return h

    c = jax.jit(f).lower(A, W).compile()
    t = H.analyze(c.as_text())
    expect = G1 * G2 * 2 * N ** 3
    assert abs(t.flops - expect) / expect < 0.05


def test_hlo_shape_bytes():
    assert H._shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert H._shape_bytes("(f32[4,4], s32[8])") == 64 + 32
    assert H._shape_bytes("pred[7]") == 7
