"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_partition.hash_partition import hash_partition
from repro.kernels.hash_partition.ref import hash_partition_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(0)


# -- flash attention -----------------------------------------------------------

FLASH_CASES = [
    # (B, H, KV, S, hd, causal, window, softcap, dtype)
    (1, 4, 2, 256, 64, True, None, 0.0, jnp.float32),
    (2, 4, 4, 128, 32, True, 64, 0.0, jnp.float32),
    (1, 2, 1, 192, 64, False, None, 0.0, jnp.float32),   # MQA + kv padding
    (1, 4, 2, 256, 64, True, None, 30.0, jnp.float32),   # softcap (gemma2)
    (1, 2, 2, 320, 128, True, 128, 50.0, jnp.float32),
    (1, 4, 2, 256, 64, True, None, 0.0, jnp.bfloat16),
    (1, 8, 2, 384, 128, True, None, 0.0, jnp.bfloat16),  # GQA group 4
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_oracle(case):
    B, H, KV, S, hd, causal, window, cap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


# -- hash partition --------------------------------------------------------------

@pytest.mark.parametrize("n,m,block", [(1000, 8, 512), (4096, 16, 1024),
                                       (5000, 7, 512), (64, 4, 64),
                                       (10_000, 256, 2048)])
def test_hash_partition_matches_oracle(n, m, block):
    keys = jax.random.randint(KEY, (n,), 0, 2 ** 31 - 1, jnp.int32)
    pids, counts = hash_partition(keys, m, block=block, interpret=True)
    rp, rc = hash_partition_ref(keys, m)
    np.testing.assert_array_equal(np.asarray(pids), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rc))
    assert int(counts.sum()) == n


@given(st.integers(2, 32),
       st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=500))
@settings(max_examples=15, deadline=None)
def test_hash_partition_property(m, key_list):
    keys = jnp.asarray(np.array(key_list, np.int32))
    pids, counts = hash_partition(keys, m, block=128, interpret=True)
    rp, rc = hash_partition_ref(keys, m)
    np.testing.assert_array_equal(np.asarray(pids), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rc))


@given(st.integers(2, 32),
       st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=400))
@settings(max_examples=15, deadline=None)
def test_device_rebucket_property(m, key_list):
    """Kernel-driven re-bucket == host stable-sort re-bucket for any keys
    (the engine device path's core invariant, DESIGN §5)."""
    from repro.core.ir import _mix_hash
    from repro.data.device_repartition import device_rebucket
    keys = np.array(key_list, np.int64)
    cols = {"k": keys, "v": np.arange(len(keys), dtype=np.float32)}
    got, counts = device_rebucket(cols, keys, m)
    pids = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % m
    order = np.argsort(pids, kind="stable")
    np.testing.assert_array_equal(counts, np.bincount(pids, minlength=m))
    np.testing.assert_array_equal(got["v"], cols["v"][order])
    np.testing.assert_array_equal(got["__key__"], keys[order])


def test_hash_partition_matches_store_dispatch():
    """Kernel hash == core.ir._mix_hash ⇒ kernel-partitioned data matches
    the engine/store partitioning decisions."""
    from repro.core.ir import _mix_hash
    keys = jax.random.randint(KEY, (512,), 0, 2 ** 31 - 1, jnp.int32)
    pids, _ = hash_partition(keys, 8, interpret=True)
    expect = (np.asarray(_mix_hash(keys)) % 8).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(pids), expect)


# -- SSD scan -----------------------------------------------------------------------

SSD_CASES = [
    (2, 128, 4, 32, 64, 32, jnp.float32),
    (1, 256, 8, 64, 128, 64, jnp.float32),
    (1, 128, 2, 16, 32, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_oracle(case):
    B, T, H, P, N, chunk, dtype = case
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (B, T, H, P), jnp.float32) * 0.5
         ).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, T, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, T, N)) * 0.3).astype(dtype)
    y, st_ = ssd_scan(x, dt, A, Bm, Cm, chunk, interpret=True)
    yr, str_ = ssd_ref(x, dt, A, Bm, Cm, chunk)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st_, np.float32),
                               np.asarray(str_, np.float32), atol=tol,
                               rtol=tol)


def test_ssd_kernel_state_feeds_decode():
    """Kernel final state == reference final state ⇒ prefill-via-kernel can
    hand off to the recurrent decode path."""
    B, T, H, P, N, chunk = 1, 64, 2, 16, 32, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.3
    _, st_k = ssd_scan(x, dt, A, Bm, Cm, chunk, interpret=True)
    _, st_r = ssd_ref(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r), atol=1e-5)
