"""Hypothesis property sweeps for the single-pass device shuffle (DESIGN §5).

The fused counting-sort path (plan cache + packed gather/scatter) must be
bit-for-bit identical to the host numpy path for *any* keys — including
heavy skew (every key equal), zero rows, and every key/payload dtype the
workloads use.  Needs the hypothesis dev extra; self-skips without it.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.ir import _mix_hash
from repro.data import device_repartition as dr
from repro.kernels.hash_partition.hash_partition import scatter_perm
from repro.kernels.hash_partition.ref import scatter_perm_ref

KEY_DTYPES = (np.int64, np.int32, np.float32, np.float64)
PAYLOAD_DTYPES = (np.float32, np.int32, np.float64, np.int64)


def _host_order(keys, m):
    pids = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % m
    return pids, np.argsort(pids, kind="stable")


# Skew comes free: small key domains (0..3) collapse most rows into one
# partition; draws of a single repeated value are the worst case.
@given(st.integers(2, 32),
       st.integers(0, len(KEY_DTYPES) - 1),
       st.integers(0, 3),                      # key domain exponent → skew
       st.lists(st.integers(0, 2 ** 31 - 1), min_size=0, max_size=400))
@settings(max_examples=25, deadline=None)
def test_fused_rebucket_equals_host_path(m, kdt, dom, raw):
    domain = 4 ** dom + 1
    keys = (np.array(raw, np.int64) % domain).astype(KEY_DTYPES[kdt])
    n = keys.shape[0]
    cols = {f"c{i}": np.arange(n, dtype=dt) * (i + 1)
            for i, dt in enumerate(PAYLOAD_DTYPES)}
    cols["mat"] = np.arange(2 * n, dtype=np.float32).reshape(n, 2)

    got, counts = dr.device_rebucket(cols, keys, m)
    pids, order = _host_order(keys, m)
    np.testing.assert_array_equal(counts, np.bincount(pids, minlength=m))
    for k, v in cols.items():
        assert got[k].dtype == v.dtype, k
        np.testing.assert_array_equal(got[k], v[order], err_msg=k)
    np.testing.assert_array_equal(got["__key__"], keys[order])


@given(st.integers(2, 24),
       st.lists(st.integers(0, 2 ** 31 - 1), min_size=0, max_size=300))
@settings(max_examples=25, deadline=None)
def test_fused_scatter_padded_equals_host_layout(m, raw):
    keys = np.array(raw, np.int64)
    n = keys.shape[0]
    data = {"k": keys, "v": np.arange(n, dtype=np.float32)}
    pids_d, hist = dr.device_partition_ids(keys, m)
    counts = np.asarray(hist).astype(np.int64) if n \
        else np.zeros(m, np.int64)
    cols = dr.device_scatter_padded(data, pids_d, counts)

    pids = np.asarray(pids_d).astype(np.int64)
    order = np.argsort(pids, kind="stable")
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    cap = int(counts.max()) if n else 1
    for k, v in data.items():
        want = np.zeros((m, cap) + v.shape[1:], v.dtype)
        sv = v[order]
        for w in range(m):
            c = counts[w]
            if c:
                want[w, :c] = sv[offsets[w]:offsets[w] + c]
        got = np.asarray(cols[k])
        assert got.dtype == v.dtype
        np.testing.assert_array_equal(got, want, err_msg=k)


@given(st.integers(1, 24),
       st.lists(st.integers(0, 23), min_size=1, max_size=300))
@settings(max_examples=25, deadline=None)
def test_scatter_perm_kernel_property(m, pid_list):
    """Counting-sort kernel == stable-argsort inverse for arbitrary pid
    multisets (any skew, any partition count ≥ observed pids)."""
    pids = np.array(pid_list, np.int32) % m
    counts = np.bincount(pids, minlength=m).astype(np.int32)
    got = scatter_perm(jnp.asarray(pids), jnp.asarray(counts),
                       block=64, interpret=True)
    want = scatter_perm_ref(jnp.asarray(pids), jnp.asarray(counts))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=200),
       st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_d2d_repartition_property(raw, m):
    """Round-robin device store → d2d hash repartition ≡ host repartition,
    for any key multiset (row preservation + co-location + exact layout)."""
    from repro.core import author_integrator, enumerate_candidates
    from repro.data.partition_store import PartitionStore
    wl = author_integrator()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    keys = np.array(raw, np.int64)
    data = {"author": keys,
            "score": np.arange(keys.size, dtype=np.float32)}
    host, dev = PartitionStore(m), PartitionStore(m, backend="device")
    new_h, _ = host.repartition(host.write("submissions", data), cand)
    new_d, _ = dev.repartition(dev.write("submissions", data), cand)
    np.testing.assert_array_equal(new_h.counts, new_d.counts)
    fh, fd = new_h.gather(), new_d.gather()
    for k in fh:
        assert fh[k].dtype == fd[k].dtype
        np.testing.assert_array_equal(fh[k], fd[k])
