import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf round 6 — ring-buffered local caches for the long-context cells.

gemma2/llama4 long_500k allocate FULL 524288-token caches for their LOCAL
attention layers (window 4096/8192).  `windowed_local_cache=True` switches
those layers to ring buffers of window size.  Hypothesis: cache argument
bytes drop ~(L/window)× on the local layers ⇒ decode working set and
memory term both shrink; global layers unchanged.
"""

import json, time, traceback
from repro.launch.dryrun import analyze_cell

CLIMBS = [
    ("gemma2-27b", "long_500k", [
        ("baseline", {}, {}),
        ("windowed_cache", {"windowed_local_cache": True}, {}),
    ]),
    ("llama4-maverick-400b-a17b", "long_500k", [
        ("baseline", {}, {}),
        ("windowed_cache", {"windowed_local_cache": True}, {}),
    ]),
    ("gemma2-27b", "decode_32k", [
        ("baseline", {}, {}),
        ("windowed_cache", {"windowed_local_cache": True}, {}),
    ]),
]

out = []
for arch, shape, variants in CLIMBS:
    for name, extra_cfg, variant in variants:
        t0 = time.time()
        try:
            rec = analyze_cell(arch, shape, extra_cfg=extra_cfg,
                               variant=variant)
            rec["climb_variant"] = name
            out.append(rec)
            ma = rec["memory_analysis"]
            print(f"== {arch} × {shape} [{name}]: "
                  f"mem={rec['memory_s']*1e3:.1f}ms "
                  f"coll={rec['collective_s']*1e3:.1f}ms "
                  f"args={ma['argument_bytes']/2**30:.2f}GiB "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            traceback.print_exc()
            out.append({"arch": arch, "shape": shape,
                        "climb_variant": name, "error": repr(e)})
with open(os.path.join(os.path.dirname(__file__), "results",
                       "hillclimb_windowed.json"), "w") as f:
    json.dump(out, f, indent=1)
print("wrote hillclimb_windowed.json")
