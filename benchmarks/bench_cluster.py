"""Cluster tier benchmarks (DESIGN §14): incremental elastic rebalancing.

Three rows, all at m=32 partitions with replication 1 (so ``bytes_moved``
is exactly the primary node-to-node stream — no replica copies muddying
the bound):

* ``cluster_rebalance_node_add_m32`` — scale-out 4 → 5 directory-nodes:
  the rebalancer streams only the partitions whose primary moved on the
  consistent-hash ring, hard-links every unchanged part, and commits
  with one epoch flip.  ``derived`` carries moved-partition count,
  bytes moved, and the incremental bound (moved/m × total padded bytes)
  the acceptance criterion pins.
* ``cluster_rebalance_node_remove_m32`` — scale-in 5 → 4: the drained
  node's partitions re-home onto survivors, same accounting.
* ``cluster_full_reshuffle_m32_to_40`` — the naive baseline elastic
  scaling competes against: changing the partition count (m=32 → 40)
  invalidates every layout, so the store re-persists every byte.  The
  incremental rows above should move a small fraction of this.
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.cluster import ClusterConfig
from repro.data.partition_store import PartitionStore

from .common import SMOKE, emit, scale

M = 32
NODES4 = ("node-0", "node-1", "node-2", "node-3")


def _dataset(n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, max(n // 16, 4), size=n).astype(np.int64),
            "a": rng.standard_normal(n).astype(np.float64),
            "b": rng.standard_normal(n).astype(np.float32)}


def _fresh_store(root: str, nodes, num_workers: int, data) -> PartitionStore:
    store = PartitionStore(
        root=root, num_workers=num_workers,
        cluster=ClusterConfig(nodes=nodes, replication=1))
    store.write("events", data)
    return store


def _total_bytes(store: PartitionStore) -> float:
    return float(store.read("events").padded_bytes)


def _bench_rebalance(name: str, n: int, repeats: int, *, add=(), remove=()):
    """Time `store.rebalance` over a membership change; fresh store per
    repeat (a rebalance mutates placement, so runs are not idempotent)."""
    data = _dataset(n)
    nodes = NODES4 if add else NODES4 + ("node-4",)
    best, res, total = float("inf"), None, 0.0
    for _ in range(repeats):
        root = tempfile.mkdtemp(prefix="lachesis-bench-cluster-")
        try:
            store = _fresh_store(root, nodes, M, data)
            total = _total_bytes(store)
            plan = store.plan_rebalance(add_nodes=add, remove_nodes=remove,
                                        reason=f"bench:{name}")
            t0 = time.perf_counter()
            r = store.rebalance(plan=plan)
            wall = time.perf_counter() - t0
            if wall < best:
                best, res = wall, r
        finally:
            shutil.rmtree(root, ignore_errors=True)
    bound = res.partitions_moved / M * total
    assert res.bytes_moved <= bound + 1e-9, \
        f"{name}: incremental bound violated ({res.bytes_moved} > {bound})"
    emit(name, best * 1e6,
         f"moved={res.partitions_moved}/{M} bytes_moved={res.bytes_moved} "
         f"bound={bound:.0f} linked={res.bytes_linked} epoch={res.epoch}")
    return best, res, total


def _bench_full_reshuffle(n: int, repeats: int):
    """Naive elastic baseline: m changes (32 → 40), so every layout is
    invalid and the whole dataset is re-persisted from scratch."""
    data = _dataset(n)
    nbytes = sum(v.nbytes for v in data.values())
    best = float("inf")
    for _ in range(repeats):
        src = tempfile.mkdtemp(prefix="lachesis-bench-cluster-")
        dst = tempfile.mkdtemp(prefix="lachesis-bench-cluster-")
        try:
            store = _fresh_store(src, NODES4, M, data)
            rows = store.read("events").gather()
            t0 = time.perf_counter()
            _fresh_store(dst, NODES4 + ("node-4",), 40,
                         {k: np.asarray(v) for k, v in rows.items()})
            best = min(best, time.perf_counter() - t0)
        finally:
            shutil.rmtree(src, ignore_errors=True)
            shutil.rmtree(dst, ignore_errors=True)
    emit("cluster_full_reshuffle_m32_to_40", best * 1e6,
         f"bytes_rewritten={nbytes} (every partition, naive baseline)")
    return best


def main() -> None:
    n = scale(400_000, 40_000)
    repeats = 1 if SMOKE else 3
    t_add, res_add, total = _bench_rebalance(
        "cluster_rebalance_node_add_m32", n, repeats, add=("node-4",))
    _bench_rebalance(
        "cluster_rebalance_node_remove_m32", n, repeats, remove=("node-4",))
    t_full = _bench_full_reshuffle(n, repeats)
    frac = res_add.bytes_moved / max(total, 1.0)
    emit("cluster_incremental_vs_full", t_add * 1e6,
         f"speedup={t_full / max(t_add, 1e-9):.1f}x "
         f"moved_frac={frac:.2f} (vs full re-shuffle)")


if __name__ == "__main__":
    main()
