"""§Roofline table builder — reads the dry-run JSONs and emits markdown.

Terms (per chip, TPU v5e): compute = FLOPs/197e12, memory = HBM bytes/819e9,
collective = collective result-bytes/50e9.  The dominant term is the
bottleneck; roofline fraction = useful MODEL_FLOPS time / dominant term.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "results")
PEAK = 197e12


def load(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)["results"]


HBM_BW = 819e9


def roofline_fraction(r: Dict) -> float:
    """Useful-work time / dominant-term time.

    train/prefill: useful = MODEL_FLOPS at peak (MFU-style).
    decode: the step is intrinsically memory-bound — useful work is reading
    the param+cache working set once (= per-device argument bytes)."""
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    dom = max(terms.values())
    if r["kind"] == "decode":
        useful_s = r["memory_analysis"]["argument_bytes"] / HBM_BW
    else:
        useful_s = r["model_flops_global"] / r["chips"] / PEAK
    return useful_s / max(dom, 1e-30)


def fmt_row(r: Dict) -> str:
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    dom = max(terms, key=terms.get)
    frac = roofline_fraction(r)
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} "
            f"| {r['collective_s'] * 1e3:.2f} | {dom} "
            f"| {r['useful_flop_ratio']:.2f} | {frac * 100:.1f}% |")


def table(paths: List[str]) -> str:
    rows = []
    for p in paths:
        if os.path.exists(p):
            rows.extend(load(p))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | compute ms | memory ms | collective ms "
           "| bottleneck | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    out.extend(fmt_row(r) for r in rows)
    return "\n".join(out)


def main():
    paths = [os.path.join(RESULTS, "dryrun_single_pod.json"),
             os.path.join(RESULTS, "dryrun_multi_pod.json")]
    print(table(paths))


if __name__ == "__main__":
    main()
