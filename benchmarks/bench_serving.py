"""Serving tier (DESIGN §11) — concurrent clients over one shared store.

Three rows:

* ``serving_throughput`` — aggregate completed requests/sec of a
  plan-cache-hit query mix at 1, 4 and 16 concurrent clients against one
  :class:`~repro.service.ServingFrontend`.  ``derived`` carries the
  per-client-count rates, the 1→16 scaling factor (the PR 6 acceptance
  bar is >2x) and the coalesced-hit rate — on a single-core host
  coalescing, not parallelism, is where the scaling comes from: identical
  queued requests share one execution.
* ``serving_mixed_throughput`` — the same ladder with every client
  opting out of coalescing (worst case: all executions run), isolating
  how much of the headline row coalescing buys.
* ``serving_p99_under_repartition`` — p50/p99 ticket latency of 16
  clients while a background thread keeps flipping the scanned table's
  layout generation.  ``failed`` must be 0: flips are invisible to
  in-flight serves (MVCC reads + transparent re-plan).
"""

from __future__ import annotations

import threading
import time

from repro.api import Session
from repro.core import Workload, enumerate_candidates
from repro.data.partition_store import PartitionStore
from repro.service import drift_tables

from .common import emit, scale


def _query() -> Workload:
    wl = Workload("serve-q")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    wl.aggregate(j, key=j["odate"], reducer="sum")
    return wl


def _seed_session() -> Session:
    store = PartitionStore(num_workers=4, backend="host",
                           max_retired_generations=16)
    sess = Session(store)
    tables = drift_tables(n_lineitem=scale(20000, 3000),
                          n_orders=scale(5000, 800),
                          n_parts=scale(500, 200))
    for name, data in tables.items():
        sess.write(name, data)
    return sess


def _drive(front, clients: int, per_client: int, coalesce: bool) -> float:
    """Aggregate completed-requests/sec for `clients` threads issuing the
    same plan-cache-hit query."""
    wl = _query()
    front.run(wl, timeout=300, block=True)          # warm plan + jit
    errors = []

    def client():
        try:
            for _ in range(per_client):
                front.run(wl, coalesce=coalesce, timeout=300, block=True)
        except BaseException as e:                  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client) for _ in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not errors, f"serving bench failed: {errors[:2]}"
    return clients * per_client / wall


def throughput_ladder() -> None:
    per_client = scale(30, 8)
    for coalesce, row in ((True, "serving_throughput"),
                          (False, "serving_mixed_throughput")):
        sess = _seed_session()
        front = sess.serve(max_workers=16, max_queue=1024)
        rates = {c: _drive(front, c, per_client, coalesce)
                 for c in (1, 4, 16)}
        st = front.stats()
        hit_rate = st["coalesced"] / max(1, st["submitted"])
        front.close()
        emit(row, 1e6 / rates[16],
             f"req_s_1={rates[1]:.1f} req_s_4={rates[4]:.1f} "
             f"req_s_16={rates[16]:.1f} "
             f"scaling_1to16={rates[16] / rates[1]:.2f}x "
             f"coalesce_rate={hit_rate:.2f}")


def latency_under_repartition() -> None:
    sess = _seed_session()
    front = sess.serve(max_workers=16, max_queue=1024)
    wl = _query()
    front.run(wl, timeout=300, block=True)
    cand = enumerate_candidates(wl.graph, "lineitem")[0]

    stop = threading.Event()
    flips = [0]

    def flipper():
        while not stop.is_set():
            sess.store.repartition(sess.store.read("lineitem"), cand,
                                   swap=True)
            flips[0] += 1

    errors = []

    def client():
        try:
            for _ in range(scale(12, 4)):
                front.run(wl, coalesce=False, timeout=300, block=True)
        except BaseException as e:                  # noqa: BLE001
            errors.append(e)

    ft = threading.Thread(target=flipper, daemon=True)
    ft.start()
    threads = [threading.Thread(target=client) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    ft.join(60)
    assert not errors, f"serves failed under repartition: {errors[:2]}"
    st = front.stats()
    front.close()
    assert st["failed"] == 0
    emit("serving_p99_under_repartition", st["p99_ms"] * 1e3,
         f"p50_ms={st['p50_ms']:.1f} p99_ms={st['p99_ms']:.1f} "
         f"flips={flips[0]} completed={st['completed']} failed=0")


def main() -> None:
    throughput_ladder()
    latency_under_repartition()


if __name__ == "__main__":
    main()
