"""Fig. 13 — impact of history collection on partitioning quality.

PageRank with 0..4 prior executions in history: with zero history the
advisor can only pick round-robin (worst); with ≥1 run — even on a
DIFFERENT input size — it recovers the url partitioner and performance is
optimized identically (the paper's size-independence claim)."""

from __future__ import annotations

import numpy as np

from repro.core import (HistoryStore, enumerate_candidates,
                        pagerank_iteration, partitioning_creation)
from repro.core.advisor import GreedySelector
from repro.core.dsl import reddit_loader
from repro.data.partition_store import PartitionStore

from .bench_pagerank import make_graph, wire_emit_fn
from .common import emit, run_consumer


def main(n_pages=200_000):
    fanout = 5
    wl = wire_emit_fn(pagerank_iteration(), fanout)
    cand = enumerate_candidates(wl.graph, "pages")[0]
    producer = reddit_loader("page-loader", "raw_pages", "pages", "json")

    walls = {}
    for n_hist in (0, 1, 2, 4):
        hist = HistoryStore()
        for t in range(n_hist):
            # historical runs on a DIFFERENT size (half) — size independence
            hist.log_workload(producer, timestamp=100.0 * t, latency=20.0,
                              input_bytes=5e8)
            hist.log_workload(wl, timestamp=100.0 * t + 50, latency=60.0,
                              input_bytes=1e9,
                              candidate_stats={cand.signature(): {
                                  "selectivity": 0.08,
                                  "distinct_keys": n_pages / 2,
                                  "num_objects": n_pages / 2}})
        dec = partitioning_creation(producer, "pages", hist,
                                    selector=GreedySelector(),
                                    dataset_bytes=1e9)
        pages, ranks = make_graph(n_pages, fanout)
        store = PartitionStore(8)
        store.write("pages", pages,
                    dec.candidate if dec.candidate.is_keyed else None)
        store.write("ranks", ranks,
                    enumerate_candidates(wl.graph, "ranks")[0]
                    if dec.candidate.is_keyed else None)
        r = run_consumer(store, wl, repeats=2)
        walls[n_hist] = r["modeled_s"]
        emit(f"history_{n_hist}_runs", r["wall_s"] * 1e6,
             f"keyed={dec.candidate.is_keyed} "
             f"normalized={walls[0] / r['modeled_s']:.2f}")
    assert walls[1] < walls[0], "one historical run must already optimize"
    assert abs(walls[1] - walls[4]) / walls[1] < 0.5, "size-independent"


if __name__ == "__main__":
    main()
