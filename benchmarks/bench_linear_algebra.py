"""Fig. 7–9 — blocked linear algebra: dense/sparse matmul, gram, regression.

The producer stores a matrix as square blocks; the consumer joins left
blocks (col id) with right blocks (row id), multiplies per pair, and
aggregates partial products.  Lachesis co-partitions on the block-id join
keys so the pairing join is worker-local."""

from __future__ import annotations

import numpy as np

from repro.core import enumerate_candidates, matmul_workload
from repro.data.partition_store import PartitionStore

from .common import emit, run_consumer

BLK = 64


def make_blocks(rows, cols, seed=0, sparsity=None):
    """Matrix (rows×cols) as flattened BLK×BLK blocks."""
    rng = np.random.default_rng(seed)
    nr, nc = rows // BLK, cols // BLK
    n = nr * nc
    vals = rng.normal(size=(n, BLK * BLK)).astype(np.float32)
    if sparsity is not None:
        mask = rng.random((n, BLK * BLK)) < sparsity
        vals = vals * mask
    rid, cid = np.divmod(np.arange(n), nc)
    return {"row_id": rid.astype(np.int64), "col_id": cid.astype(np.int64),
            "vals": vals}, (nr, nc)


def wire_gemm(wl, nc_out):
    def gemm(cols):
        a = cols["vals"].reshape(-1, BLK, BLK)
        b = cols["r_vals"].reshape(-1, BLK, BLK) if "r_vals" in cols \
            else cols["vals"].reshape(-1, BLK, BLK)
        prod = np.einsum("nij,njk->nik", a, b).reshape(-1, BLK * BLK)
        out_id = cols["row_id"] * nc_out + cols["r_col_id"] \
            if "r_col_id" in cols else cols["row_id"]
        return {"out_block_id": out_id.astype(np.int64), "vals": prod}
    for node in wl.graph.nodes.values():
        if node.params.get("tag") == "mkl_gemm":
            node.params["fn"] = gemm
    return wl


def run_case(name, x_rows, sparsity=None, workers=8):
    """LHS: 1024 × x; RHS: x × 1024 (paper's 1000 × x shape, block-rounded)."""
    lhs, _ = make_blocks(1024, x_rows, seed=0, sparsity=sparsity)
    rhs, (nr2, nc2) = make_blocks(x_rows, 1024, seed=1, sparsity=sparsity)
    wl = wire_gemm(matmul_workload(), nc2)

    lhs_cand = enumerate_candidates(wl.graph, "lhs_blocks")[0]
    rhs_cand = enumerate_candidates(wl.graph, "rhs_blocks")[0]

    res = {}
    for mode, cands in (("rr", (None, None)),
                        ("lachesis", (lhs_cand, rhs_cand))):
        store = PartitionStore(workers)
        store.write("lhs_blocks", lhs, cands[0])
        store.write("rhs_blocks", rhs, cands[1])
        res[mode] = run_consumer(store, wl, repeats=2)
    sw = res["rr"]["wall_s"] / res["lachesis"]["wall_s"]
    sm = res["rr"]["modeled_s"] / res["lachesis"]["modeled_s"]
    emit(f"linalg_{name}", res["lachesis"]["wall_s"] * 1e6,
         f"speedup_wall={sw:.2f}x speedup_modeled={sm:.2f}x "
         f"elided={res['lachesis']['elided']}")
    return sw


def main():
    for x in (4096, 16384):
        run_case(f"dense_x{x}", x)
    run_case("sparse_x16384_s0.001", 16384, sparsity=0.001)
    # gram matrix: Xᵀ X shares the block-id partitioner (same join shape)
    run_case("gram_x8192", 8192)
    run_case("regression_x8192", 8192)    # bottleneck is the matmul join


if __name__ == "__main__":
    main()
