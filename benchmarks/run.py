"""Benchmark suite entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Pass ``--json PATH`` (by
convention ``BENCH_<tag>.json``) to additionally snapshot the emitted rows
(collected in ``common.ROWS``) — see benchmarks/README.md for the
methodology.  The dry-run/roofline cells
(which need the 512-device env flag) run via ``repro.launch.dryrun`` as a
separate process — see EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import json
import sys
import time
import traceback


def main() -> None:
    from . import (bench_reddit, bench_pagerank, bench_linear_algebra,
                   bench_tpch, bench_overhead, bench_drl_training,
                   bench_history, bench_kernels, bench_autopilot,
                   bench_storage, bench_serving, bench_cluster)
    argv = sys.argv[1:]
    json_path = None
    if "--json" in argv:
        i = argv.index("--json") + 1
        if i >= len(argv):
            sys.exit("usage: python -m benchmarks.run [--json BENCH_<tag>.json]")
        json_path = argv[i]
    suites = [
        ("reddit(Fig5,Tab3)", bench_reddit.main),
        ("pagerank(Fig6)", bench_pagerank.main),
        ("linear_algebra(Fig7-9)", bench_linear_algebra.main),
        ("tpch(Fig10)", bench_tpch.main),
        ("overhead(Tab2,Fig11)", bench_overhead.main),
        ("drl_training(Fig12)", bench_drl_training.main),
        ("history(Fig13)", bench_history.main),
        ("kernels(Pallas)", bench_kernels.main),
        ("autopilot(service)", bench_autopilot.main),
        ("storage(durable)", bench_storage.main),
        ("serving(tier)", bench_serving.main),
        ("cluster(tier)", bench_cluster.main),
    ]
    from .common import ROWS
    print("name,us_per_call,derived")
    failures = []
    timings = {}
    try:
        for name, fn in suites:
            t0 = time.time()
            try:
                fn()
                timings[name] = time.time() - t0
                print(f"# {name} done in {timings[name]:.1f}s",
                      file=sys.stderr)
            except Exception:
                traceback.print_exc()
                failures.append(name)
    finally:
        if json_path:
            with open(json_path, "w") as f:
                json.dump({"rows": ROWS, "suite_seconds": timings,
                           "failures": failures}, f, indent=1)
            print(f"# wrote {json_path}", file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
