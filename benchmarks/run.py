"""Benchmark suite entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The dry-run/roofline cells
(which need the 512-device env flag) run via ``repro.launch.dryrun`` as a
separate process — see EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (bench_reddit, bench_pagerank, bench_linear_algebra,
                   bench_tpch, bench_overhead, bench_drl_training,
                   bench_history, bench_kernels)
    suites = [
        ("reddit(Fig5,Tab3)", bench_reddit.main),
        ("pagerank(Fig6)", bench_pagerank.main),
        ("linear_algebra(Fig7-9)", bench_linear_algebra.main),
        ("tpch(Fig10)", bench_tpch.main),
        ("overhead(Tab2,Fig11)", bench_overhead.main),
        ("drl_training(Fig12)", bench_drl_training.main),
        ("history(Fig13)", bench_history.main),
        ("kernels(Pallas)", bench_kernels.main),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
