"""Fig. 10 — TPC-H-like relational queries on the UDF engine.

Synthetic lineitem/orders/part tables; three join-heavy queries shaped like
the ones the paper reports wins on (Q02/Q04/Q17 families): the partitioner
candidates are the join keys, and Lachesis partitions the loaded tables so
the joins run locally."""

from __future__ import annotations

import numpy as np

from repro.core import Workload, enumerate_candidates
from repro.data.partition_store import PartitionStore

from .common import emit, run_consumer

SF = 0.02   # scale factor vs TPC-H SF1 row counts (CPU-friendly)


def make_tables(seed=0):
    rng = np.random.default_rng(seed)
    n_orders = int(1_500_000 * SF)
    n_lines = int(6_000_000 * SF)
    n_parts = int(200_000 * SF)
    orders = {"orderkey": np.arange(n_orders, dtype=np.int64),
              "custkey": rng.integers(0, n_orders // 10, n_orders),
              "odate": rng.integers(0, 2556, n_orders).astype(np.int32)}
    lineitem = {"orderkey": rng.integers(0, n_orders, n_lines),
                "partkey": rng.integers(0, n_parts, n_lines),
                "qty": rng.integers(1, 50, n_lines).astype(np.float32),
                "price": rng.normal(100, 20, n_lines).astype(np.float32)}
    part = {"partkey": np.arange(n_parts, dtype=np.int64),
            "size": rng.integers(1, 50, n_parts).astype(np.int32)}
    return orders, lineitem, part


def q_orders_lineitem() -> Workload:
    """Q04/Q12-family: join lineitem with orders on orderkey, aggregate."""
    wl = Workload("q04-like")
    li = wl.scan("lineitem")
    od = wl.scan("orders")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="li_orders")
    agg = wl.aggregate(j, key=j["odate"], reducer="sum")
    wl.write(agg, "q04_out")
    return wl


def q_lineitem_part() -> Workload:
    """Q17-family: join lineitem with part on partkey, aggregate qty."""
    wl = Workload("q17-like")
    li = wl.scan("lineitem")
    pt = wl.scan("part")
    j = wl.join(li, pt, left_key=li["partkey"], right_key=pt["partkey"],
                tag="li_part")
    agg = wl.aggregate(j, key=j["size"], reducer="mean")
    wl.write(agg, "q17_out")
    return wl


def q_orders_filter_join() -> Workload:
    """Q02-family: selective probe join (orders → lineitem)."""
    wl = Workload("q02-like")
    od = wl.scan("orders")
    li = wl.scan("lineitem")
    j = wl.join(li, od, left_key=li["orderkey"], right_key=od["orderkey"],
                tag="probe")
    f = wl.filter(j, j["qty"] > 40)
    agg = wl.aggregate(f, key=f["custkey"], reducer="sum")
    wl.write(agg, "q02_out")
    return wl


def run_query(name, wl, tables, keys, workers=8):
    res = {}
    for mode in ("rr", "lachesis"):
        store = PartitionStore(workers)
        for tname, data in tables.items():
            cand = None
            if mode == "lachesis" and tname in keys:
                cands = enumerate_candidates(wl.graph, tname)
                cand = cands[0] if cands else None
            store.write(tname, data, cand)
        # best-of-4: wall ratios on shared/1-core hosts are noisy enough at
        # best-of-2 to swing 2x run-to-run (see README watchlist, PR6)
        res[mode] = run_consumer(store, wl, repeats=4)
    sw = res["rr"]["wall_s"] / res["lachesis"]["wall_s"]
    sm = res["rr"]["modeled_s"] / res["lachesis"]["modeled_s"]
    # absolute walls in the snapshot: a ratio shift caused by the *baseline*
    # moving (different host, cold caches) is visible, not silent
    emit(f"tpch_{name}", res["lachesis"]["wall_s"] * 1e6,
         f"speedup_wall={sw:.2f}x speedup_modeled={sm:.2f}x "
         f"rr_wall_ms={res['rr']['wall_s'] * 1e3:.1f} "
         f"lx_wall_ms={res['lachesis']['wall_s'] * 1e3:.1f} "
         f"shuffles {res['rr']['shuffles']}->{res['lachesis']['shuffles']}")
    return sw


def main():
    orders, lineitem, part = make_tables()
    tabs = {"orders": orders, "lineitem": lineitem, "part": part}
    run_query("q04like", q_orders_lineitem(), tabs, ("orders", "lineitem"))
    run_query("q17like", q_lineitem_part(), tabs, ("lineitem", "part"))
    run_query("q02like", q_orders_filter_join(), tabs,
              ("orders", "lineitem"))


if __name__ == "__main__":
    main()
