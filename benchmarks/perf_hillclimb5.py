import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf round 5 — final variant matrix under the corrected byte accounting
(dynamic-slice = slice bytes; flash-decode gated as an explicit variant)."""

import json, time, traceback
from repro.launch.dryrun import analyze_cell

CLIMBS = [
    ("qwen1.5-110b", "decode_32k", False, [
        ("baseline", "no-flash, hd-sharded cache", {}, {}),
        ("flash_decode", "online-softmax key-block scan: scores never at "
         "full length; −8% memory in CPU accounting (bigger on TPU where "
         "the slice never hits a fusion boundary)", {},
         {"flash_decode": True}),
    ]),
    ("deepseek-v2-236b", "decode_32k", False, [
        ("baseline", "naive MLA", {}, {}),
        ("absorbed", "latent-space scores", {"mla_absorbed": True}, {}),
        ("absorbed_seqshard", "plus L-sharded latent cache",
         {"mla_absorbed": True}, {"cache_seq_shard": True}),
    ]),
    ("llama4-maverick-400b-a17b", "train_4k", True, [
        ("baseline", "accum=4", {}, {}),
        ("accum1", "single macrobatch: FSDP gathers once", {"accum_steps": 1},
         {}),
    ]),
    ("deepseek-v2-236b", "train_4k", False, [
        ("baseline", "accum=4 full remat", {}, {}),
        ("accum8", "live-set knob", {"accum_steps": 8}, {}),
    ]),
]

out = []
for arch, shape, multi_pod, variants in CLIMBS:
    for name, hypothesis, extra_cfg, variant in variants:
        t0 = time.time()
        try:
            rec = analyze_cell(arch, shape, multi_pod=multi_pod,
                               extra_cfg=extra_cfg, variant=variant)
            rec["climb_variant"] = name; rec["hypothesis"] = hypothesis
            out.append(rec)
            print(f"== {arch} × {shape} [{name}]: "
                  f"comp={rec['compute_s']*1e3:.1f}ms "
                  f"mem={rec['memory_s']*1e3:.1f}ms "
                  f"coll={rec['collective_s']*1e3:.1f}ms "
                  f"temp={rec['memory_analysis']['temp_bytes']/2**30:.1f}GiB "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            traceback.print_exc()
            out.append({"arch": arch, "shape": shape,
                        "climb_variant": name, "error": repr(e)})
with open(os.path.join(os.path.dirname(__file__), "results",
                       "hillclimb_final.json"), "w") as f:
    json.dump(out, f, indent=1)
print("wrote hillclimb_final.json")
