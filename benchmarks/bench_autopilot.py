"""Autopilot service benchmark (DESIGN §8) — background repartition rows.

Times the observe → decide → repartition loop end to end on the drift
scenario: consumer wall before the service acts, the background
repartition itself (tick decision + apply + generation swap, d2d on the
device backend), the post-decision consumer (shuffles elided) and the
post-drift re-repartition.  Also prices the observer: engine wall with
auto-recording on vs off.
"""

from __future__ import annotations

import time

from repro.api import Session
from repro.core.history import HistoryStore
from repro.data.partition_store import PartitionStore

from .common import emit, scale


def drift_rows(backend: str) -> None:
    from repro.service import run_drift_scenario
    rep = run_drift_scenario(backend=backend,
                             n_lineitem=scale(200_000, 12_000),
                             n_orders=scale(20_000, 1_500),
                             n_parts=scale(2_000, 300))
    pre = rep.phase_a[-1]
    emit(f"autopilot_consumer_pre_{backend}", pre.wall_s * 1e6,
         f"round-robin layout shuffles={pre.shuffles} elided={pre.elided}")
    applied = {a.dataset: a for a in rep.tick_a.applied}
    li = applied["lineitem"]
    emit(f"autopilot_bg_repartition_{backend}",
         li.repartition_wall_s * 1e6,
         f"lineitem -> {li.decision.candidate.signature()} path={li.path} "
         f"gen={li.generation} moved={li.moved_bytes} "
         f"benefit={li.score.benefit_s * 1e3:.1f}ms/window "
         f"cost={li.score.repartition_s * 1e3:.1f}ms "
         f"decided_in={li.decision.elapsed_s * 1e3:.1f}ms")
    emit(f"autopilot_consumer_post_{backend}", rep.post_a.wall_s * 1e6,
         f"speedup={pre.wall_s / max(rep.post_a.wall_s, 1e-12):.2f}x "
         f"shuffles={rep.post_a.shuffles} elided={rep.post_a.elided}")
    applied_b = {a.dataset: a for a in rep.tick_b.applied}
    lib = applied_b["lineitem"]
    emit(f"autopilot_drift_repartition_{backend}",
         lib.repartition_wall_s * 1e6,
         f"lineitem -> {lib.decision.candidate.signature()} path={lib.path} "
         f"gen={lib.generation} (orderkey mix aged out of window)")
    emit(f"autopilot_consumer_postdrift_{backend}", rep.post_b.wall_s * 1e6,
         f"shuffles={rep.post_b.shuffles} elided={rep.post_b.elided}")


def observer_overhead() -> None:
    """Auto-recording cost: session wall with history on vs off."""
    from repro.service import drift_tables, q_orderkey
    tables = drift_tables(n_lineitem=scale(200_000, 12_000),
                          n_orders=scale(20_000, 1_500))
    store = PartitionStore(num_workers=8)
    for name in ("lineitem", "orders"):
        store.write(name, tables[name])
    sess = Session(store)
    wl = q_orderkey()
    reps = 5

    def best_wall(history):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            sess.run(wl, history=history,
                     timestamp=0.0 if history else None)
            best = min(best, time.perf_counter() - t0)
        return best

    base = best_wall(None)
    sess.run(wl)         # warm
    observed = best_wall(HistoryStore())
    emit("autopilot_observer_overhead", (observed - base) * 1e6,
         f"auto ExecutionRecord per run: {observed / base - 1:+.1%} of "
         f"{base * 1e3:.1f}ms consumer wall")


def main() -> None:
    for backend in ("host", "device"):
        drift_rows(backend)
    observer_overhead()


if __name__ == "__main__":
    main()
