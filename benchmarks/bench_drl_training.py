"""Fig. 12 — DRL training effectiveness on the trace-driven simulator.

Reports the loss trajectory and the policy's achieved reward vs the oracle
(exhaustive best action), mirroring the paper's §5.4 setup (trace-driven
workload sampling, A3C actor-critic 128/64)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.drl.agent import A3CAgent, A3CConfig, Transition
from repro.core.drl.env import TraceSimulator, tpch_like_library

from .common import emit


def evaluate(agent, sim, n=150, seed=123):
    rng = np.random.default_rng(seed)
    tot, opt = 0.0, 0.0
    for _ in range(n):
        wl = sim.sample_workload()
        s, m = sim.state_of(wl)
        tot += sim.reward_of(wl, agent.select(s, m, greedy=True))
        opt += sim.reward_of(wl, sim.best_action(wl))
    return tot / n, opt / n


def main(epochs=80, batch=16):
    queries, cfg = tpch_like_library()
    sim = TraceSimulator(queries, cfg)
    agent = A3CAgent(A3CConfig(state_dim=sim.state_dim,
                               num_actions=cfg.num_candidates, seed=0))
    r0, ropt = evaluate(agent, sim)
    t0 = time.perf_counter()
    losses = []
    for ep in range(epochs):
        batch_t = []
        for _ in range(batch):
            wl = sim.sample_workload()
            s, m = sim.state_of(wl)
            a = agent.select(s, m)
            batch_t.append(Transition(s, a, sim.reward_of(wl, a), m))
        loss, aux = agent.train_batch(batch_t)
        losses.append(loss)
        if ep % 20 == 0:
            emit(f"drl_epoch_{ep:03d}", 0.0,
                 f"loss={loss:.3f} entropy={aux['entropy']:.3f}")
    train_s = time.perf_counter() - t0
    r1, _ = evaluate(agent, sim)
    emit("drl_training", train_s * 1e6 / epochs,
         f"reward {r0:.3f}->{r1:.3f} (oracle {ropt:.3f}) "
         f"loss {losses[0]:.2f}->{losses[-1]:.2f} epochs={epochs}")
    assert r1 > r0, "DRL training must improve the policy"


if __name__ == "__main__":
    main()
