"""Fig. 6 + Fig. 13/14 support — PageRank analytics workflow.

Iterative join(pages, ranks) ⊳ flatten contribs ⊳ keyed aggregate.  With
Lachesis the pages/ranks partitionings match the join keys, so every
iteration's two join shuffles are elided (the paper's amortization argument
for intra-application partitioning disappears — persistence wins on the
FIRST iteration)."""

from __future__ import annotations

import numpy as np

from repro.core import enumerate_candidates, pagerank_iteration
from repro.data.partition_store import PartitionStore

from .common import emit, run_consumer

DAMPING = 0.85


def make_graph(n_pages, fanout=5, seed=0):
    rng = np.random.default_rng(seed)
    pages = {"url": np.arange(n_pages, dtype=np.int64),
             "neighbors": rng.integers(0, n_pages,
                                       (n_pages, fanout)).astype(np.int64)}
    ranks = {"url": np.arange(n_pages, dtype=np.int64),
             "rank": np.full(n_pages, 1.0 / n_pages, np.float64)}
    return pages, ranks


def wire_emit_fn(wl, fanout):
    def emit_contribs(cols):
        contrib = np.repeat((cols["rank"] / fanout)[:, None], fanout, 1)
        return {"url": cols["neighbors"], "contrib": contrib}

    def finish_ranks(cols):
        rank = (1 - DAMPING) + DAMPING * cols["contrib"]
        return {"url": cols["key"], "rank": rank}

    for node in wl.graph.nodes.values():
        if node.params.get("tag") == "emit_contribs":
            node.params["fn"] = emit_contribs
        if node.params.get("tag") == "finish_ranks":
            node.params["fn"] = finish_ranks
    return wl


def run_case(n_pages, iters=3, workers=8):
    fanout = 5
    wl = wire_emit_fn(pagerank_iteration(), fanout)
    pages, ranks = make_graph(n_pages, fanout)
    page_cand = enumerate_candidates(wl.graph, "pages")[0]
    rank_cand = enumerate_candidates(wl.graph, "ranks")[0]

    results = {}
    for mode, cands in (("rr", (None, None)),
                        ("lachesis", (page_cand, rank_cand))):
        store = PartitionStore(workers)
        store.write("pages", pages, cands[0])
        store.write("ranks", ranks, cands[1])
        tot = {"wall_s": 0.0, "modeled_s": 0.0, "shuffle_bytes": 0}
        for _ in range(iters):
            r = run_consumer(store, wl, repeats=1)
            for k in tot:
                tot[k] += r[k]
        results[mode] = tot
    sw = results["rr"]["wall_s"] / results["lachesis"]["wall_s"]
    sm = results["rr"]["modeled_s"] / results["lachesis"]["modeled_s"]
    emit(f"pagerank_{n_pages}", results["lachesis"]["wall_s"] * 1e6 / iters,
         f"speedup_wall={sw:.2f}x speedup_modeled={sm:.2f}x iters={iters} "
         f"bytes_saved={results['rr']['shuffle_bytes']}")
    return sw


def main():
    for n in (100_000, 400_000, 1_000_000):
        run_case(n)


if __name__ == "__main__":
    main()
