"""Tab. 2 + Tab. 3 + Fig. 11 — offline/online overhead analysis.

Offline (Tab. 2): skeleton-graph construction + IR signature creation over
synthetic trace archives shaped like the WTA sources (workflow count ×
tasks-per-workflow).  Online consumer side (Fig. 11): Alg. 4 matching cost
per query.  Producer side (Tab. 3) is measured in bench_reddit.

Repartition backends (DESIGN §5): host-vs-device repartition comparison on
the TPC-H, Reddit, and PageRank workloads — the same consumer run over a
round-robin store (every shuffle real) with the numpy path and with the
Pallas hash-partition kernel path.  Off-TPU the kernel runs in interpret
mode (Python-speed), so the comparison there is a correctness/coverage
signal, not a perf number; on TPU the same rows measure the compiled path.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (HistoryStore, author_integrator,
                        enumerate_candidates, pagerank_iteration,
                        partitioning_match)
from repro.core.dsl import reddit_loader
from repro.data.partition_store import PartitionStore
from repro.core.history import ExecutionRecord

from .common import emit, run_consumer

# (name, workflows, tasks/workflow) — WTA-shaped, scaled to CPU budget
TRACES = [
    ("Pegasus-like", 56, 180),
    ("Shell-like", 3_403, 3),
    ("Askalon-like", 4_583, 36),
    ("SPEC-like", 400, 70),
    ("Google-like-1pct", 4_941, 36),
]


def synth_history(n_workflows, tasks_per_wf, seed=0) -> HistoryStore:
    rng = np.random.default_rng(seed)
    hist = HistoryStore()
    n_groups = max(4, n_workflows // 50)    # recurrence: ~50 runs per group
    for i in range(n_workflows):
        g = int(rng.integers(0, n_groups))
        hist.log(ExecutionRecord(
            app_id=f"app{g}", timestamp=float(i),
            ir_signature=f"sig{g}",
            inputs=[f"ds{g}"], outputs=[f"ds{(g + 1) % n_groups}"],
            latency=float(rng.uniform(1, 100)),
            input_bytes=float(rng.uniform(1e8, 1e10))))
    return hist


def offline_overheads():
    for name, wf, tpw in TRACES:
        hist = synth_history(wf, tpw)
        t0 = time.perf_counter()
        groups, edges = hist.skeleton_graph()
        sg_ms = (time.perf_counter() - t0) * 1e3

        # signature creation for `tasks` IR graphs (reuse the reddit IR as a
        # representative task graph; paper hashes each workload's IR once)
        wl = author_integrator()
        n_sigs = min(tpw, 200)
        t0 = time.perf_counter()
        for _ in range(n_sigs):
            wl.graph.graph_signature()
        sn_ms = (time.perf_counter() - t0) * 1e3 * (tpw / n_sigs)
        emit(f"offline_{name}", sg_ms * 1e3,
             f"workflows={wf} SG-latency={sg_ms:.1f}ms "
             f"SN-latency~{sn_ms:.1f}ms groups={len(groups)} "
             f"edges={len(edges)}")


def online_consumer_matching():
    wl = author_integrator()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        res = partitioning_match(cand, "submissions", wl.graph)
    per = (time.perf_counter() - t0) / n
    emit("online_consumer_match", per * 1e6,
         f"matched={res.matched} checked={res.checked} "
         f"(paper Fig.11: sub-second; here {per * 1e3:.3f} ms/query)")


def _backend_cases():
    """The three acceptance workloads, each with round-robin-stored inputs
    so every partition node performs a real repartition."""
    from .bench_pagerank import make_graph, wire_emit_fn
    from .bench_reddit import make_data
    from .bench_tpch import make_tables, q_orders_lineitem

    subs, auths = make_data(100_000, 25_000)
    yield ("reddit", author_integrator(),
           {"submissions": subs, "authors": auths})

    pages, ranks = make_graph(100_000, fanout=5)
    yield ("pagerank", wire_emit_fn(pagerank_iteration(), 5),
           {"pages": pages, "ranks": ranks})

    orders, lineitem, part = make_tables()
    yield ("tpch_q04like", q_orders_lineitem(),
           {"orders": orders, "lineitem": lineitem, "part": part})


def repartition_backends(workers: int = 8):
    import jax
    from repro.configs import lachesis_paper
    on_tpu = jax.default_backend() == "tpu"
    backends = lachesis_paper.get().engine_backends
    for name, wl, tables in _backend_cases():
        res = {}
        for backend in backends:
            store = PartitionStore(workers)
            for tname, data in tables.items():
                store.write(tname, data)           # rr ⇒ shuffles all run
            res[backend] = run_consumer(store, wl, repeats=2,
                                        backend=backend)
        h, d = res["host"], res["device"]
        assert d["device_repartitions"] == d["shuffles"] > 0
        mode = "compiled" if on_tpu else "interpret"
        emit(f"repartition_{name}_device", d["wall_s"] * 1e6,
             f"host={h['wall_s'] * 1e6:.0f}us "
             f"device/host={d['wall_s'] / h['wall_s']:.2f}x "
             f"shuffles={d['shuffles']} "
             f"device_repartitions={d['device_repartitions']} "
             f"bytes={d['shuffle_bytes']} (kernel {mode} mode)")


def main():
    offline_overheads()
    online_consumer_matching()
    repartition_backends()


if __name__ == "__main__":
    main()
