"""Tab. 2 + Tab. 3 + Fig. 11 — offline/online overhead analysis.

Offline (Tab. 2): skeleton-graph construction + IR signature creation over
synthetic trace archives shaped like the WTA sources (workflow count ×
tasks-per-workflow).  Online consumer side (Fig. 11): Alg. 4 matching cost
per query.  Producer side (Tab. 3) is measured in bench_reddit.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (HistoryStore, author_integrator,
                        enumerate_candidates, partitioning_match)
from repro.core.dsl import reddit_loader
from repro.core.history import ExecutionRecord

from .common import emit

# (name, workflows, tasks/workflow) — WTA-shaped, scaled to CPU budget
TRACES = [
    ("Pegasus-like", 56, 180),
    ("Shell-like", 3_403, 3),
    ("Askalon-like", 4_583, 36),
    ("SPEC-like", 400, 70),
    ("Google-like-1pct", 4_941, 36),
]


def synth_history(n_workflows, tasks_per_wf, seed=0) -> HistoryStore:
    rng = np.random.default_rng(seed)
    hist = HistoryStore()
    n_groups = max(4, n_workflows // 50)    # recurrence: ~50 runs per group
    for i in range(n_workflows):
        g = int(rng.integers(0, n_groups))
        hist.log(ExecutionRecord(
            app_id=f"app{g}", timestamp=float(i),
            ir_signature=f"sig{g}",
            inputs=[f"ds{g}"], outputs=[f"ds{(g + 1) % n_groups}"],
            latency=float(rng.uniform(1, 100)),
            input_bytes=float(rng.uniform(1e8, 1e10))))
    return hist


def offline_overheads():
    for name, wf, tpw in TRACES:
        hist = synth_history(wf, tpw)
        t0 = time.perf_counter()
        groups, edges = hist.skeleton_graph()
        sg_ms = (time.perf_counter() - t0) * 1e3

        # signature creation for `tasks` IR graphs (reuse the reddit IR as a
        # representative task graph; paper hashes each workload's IR once)
        wl = author_integrator()
        n_sigs = min(tpw, 200)
        t0 = time.perf_counter()
        for _ in range(n_sigs):
            wl.graph.graph_signature()
        sn_ms = (time.perf_counter() - t0) * 1e3 * (tpw / n_sigs)
        emit(f"offline_{name}", sg_ms * 1e3,
             f"workflows={wf} SG-latency={sg_ms:.1f}ms "
             f"SN-latency~{sn_ms:.1f}ms groups={len(groups)} "
             f"edges={len(edges)}")


def online_consumer_matching():
    wl = author_integrator()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        res = partitioning_match(cand, "submissions", wl.graph)
    per = (time.perf_counter() - t0) / n
    emit("online_consumer_match", per * 1e6,
         f"matched={res.matched} checked={res.checked} "
         f"(paper Fig.11: sub-second; here {per * 1e3:.3f} ms/query)")


def main():
    offline_overheads()
    online_consumer_matching()


if __name__ == "__main__":
    main()
