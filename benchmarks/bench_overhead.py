"""Tab. 2 + Tab. 3 + Fig. 11 — offline/online overhead analysis.

Offline (Tab. 2): skeleton-graph construction + IR signature creation over
synthetic trace archives shaped like the WTA sources (workflow count ×
tasks-per-workflow).  Online consumer side (Fig. 11): Alg. 4 matching cost
per query.  Producer side (Tab. 3) is measured in bench_reddit.

Repartition backends (DESIGN §5): host-vs-device repartition comparison on
the TPC-H, Reddit, and PageRank workloads — the same consumer run over a
round-robin store (every shuffle real) with the numpy path and with the
Pallas hash-partition kernel path.  Off-TPU the kernel runs in interpret
mode (Python-speed), so the comparison there is a correctness/coverage
signal, not a perf number; on TPU the same rows measure the compiled path.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (HistoryStore, author_integrator,
                        enumerate_candidates, pagerank_iteration,
                        partitioning_match)
from repro.core.dsl import reddit_loader
from repro.data.partition_store import PartitionStore
from repro.core.history import ExecutionRecord

from .common import emit, run_consumer, scale

# (name, workflows, tasks/workflow) — WTA-shaped, scaled to CPU budget
TRACES = [
    ("Pegasus-like", 56, 180),
    ("Shell-like", 3_403, 3),
    ("Askalon-like", 4_583, 36),
    ("SPEC-like", 400, 70),
    ("Google-like-1pct", 4_941, 36),
]


def synth_history(n_workflows, tasks_per_wf, seed=0) -> HistoryStore:
    rng = np.random.default_rng(seed)
    hist = HistoryStore()
    n_groups = max(4, n_workflows // 50)    # recurrence: ~50 runs per group
    for i in range(n_workflows):
        g = int(rng.integers(0, n_groups))
        hist.log(ExecutionRecord(
            app_id=f"app{g}", timestamp=float(i),
            ir_signature=f"sig{g}",
            inputs=[f"ds{g}"], outputs=[f"ds{(g + 1) % n_groups}"],
            latency=float(rng.uniform(1, 100)),
            input_bytes=float(rng.uniform(1e8, 1e10))))
    return hist


def offline_overheads():
    for name, wf, tpw in TRACES:
        wf, tpw = scale(wf, 200), scale(tpw, 20)
        hist = synth_history(wf, tpw)
        t0 = time.perf_counter()
        groups, edges = hist.skeleton_graph()
        sg_ms = (time.perf_counter() - t0) * 1e3

        # signature creation for `tasks` IR graphs (reuse the reddit IR as a
        # representative task graph; paper hashes each workload's IR once)
        wl = author_integrator()
        n_sigs = min(tpw, 200)
        t0 = time.perf_counter()
        for _ in range(n_sigs):
            wl.graph.graph_signature()
        sn_ms = (time.perf_counter() - t0) * 1e3 * (tpw / n_sigs)
        emit(f"offline_{name}", sg_ms * 1e3,
             f"workflows={wf} SG-latency={sg_ms:.1f}ms "
             f"SN-latency~{sn_ms:.1f}ms groups={len(groups)} "
             f"edges={len(edges)}")


def online_consumer_matching():
    wl = author_integrator()
    cand = enumerate_candidates(wl.graph, "submissions")[0]
    n = scale(2000, 200)
    t0 = time.perf_counter()
    for _ in range(n):
        res = partitioning_match(cand, "submissions", wl.graph)
    per = (time.perf_counter() - t0) / n
    emit("online_consumer_match", per * 1e6,
         f"matched={res.matched} checked={res.checked} "
         f"(paper Fig.11: sub-second; here {per * 1e3:.3f} ms/query)")


def _backend_cases():
    """The three acceptance workloads, each with round-robin-stored inputs
    so every partition node performs a real repartition."""
    from .bench_pagerank import make_graph, wire_emit_fn
    from .bench_reddit import make_data
    from .bench_tpch import make_tables, q_orders_lineitem

    subs, auths = make_data(scale(100_000, 5_000), scale(25_000, 1_200))
    yield ("reddit", author_integrator(),
           {"submissions": subs, "authors": auths})

    pages, ranks = make_graph(scale(100_000, 5_000), fanout=5)
    yield ("pagerank", wire_emit_fn(pagerank_iteration(), 5),
           {"pages": pages, "ranks": ranks})

    orders, lineitem, part = make_tables()
    yield ("tpch_q04like", q_orders_lineitem(),
           {"orders": orders, "lineitem": lineitem, "part": part})


def repartition_backends(workers: int = 8):
    import jax
    from repro.configs import lachesis_paper
    on_tpu = jax.default_backend() == "tpu"
    backends = lachesis_paper.get().engine_backends
    for name, wl, tables in _backend_cases():
        res = {}
        for backend in backends:
            store = PartitionStore(workers)
            for tname, data in tables.items():
                store.write(tname, data)           # rr ⇒ shuffles all run
            res[backend] = run_consumer(store, wl, repeats=2,
                                        backend=backend)
        h, d = res["host"], res["device"]
        assert d["device_repartitions"] == d["shuffles"] > 0
        mode = "fused kernel plans" if on_tpu else "hostperm plans"
        emit(f"repartition_{name}_device", d["wall_s"] * 1e6,
             f"host={h['wall_s'] * 1e6:.0f}us "
             f"device/host={d['wall_s'] / h['wall_s']:.2f}x "
             f"shuffles={d['shuffles']} "
             f"device_repartitions={d['device_repartitions']} "
             f"bytes={d['shuffle_bytes']} ({mode})")


# -- single-pass device shuffle (ISSUE 2): argsort vs counting-sort plans ----

def _shuffle_data(n: int, m: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    cols = {"score": rng.normal(size=n).astype(np.float32),
            "weight": rng.normal(size=n).astype(np.float32),
            "ups": rng.integers(0, 1000, n).astype(np.int32),
            "vec": rng.normal(size=(n, 2)).astype(np.float32),
            "author": rng.integers(0, n, n).astype(np.int64)}  # hybrid 64-bit
    keys = cols["author"]
    return cols, keys


def _legacy_rebucket(columns, key_vals, m):
    """The PR 1 device re-bucket, reproduced for comparison: un-jitted
    O(N log N) ``jnp.argsort`` + one eager gather and one host sync *per
    column* (pids via the jitted oracle so the comparison isolates the
    shuffle, not interpret-mode kernel overhead)."""
    import jax.numpy as jnp
    from repro.data.device_repartition import (device_partition_ids,
                                               dtype_roundtrips)
    key_vals = np.asarray(key_vals).reshape(-1)
    pids, hist = device_partition_ids(key_vals, m, use_kernel=False)
    order = jnp.argsort(pids, stable=True)
    out = {}
    for k, v in columns.items():
        v = np.asarray(v)
        if dtype_roundtrips(v.dtype):
            out[k] = np.asarray(jnp.take(jnp.asarray(v), order, axis=0))
        else:
            out[k] = v[np.asarray(order)]
    out["__key__"] = out.get("__key__", key_vals[np.asarray(order)])
    return out, np.asarray(hist).astype(np.int64)


def _best_of(fn, repeats=3):
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None or dt < best else best
    return best, out


def device_repartition_scaling(n: int = 1_000_000, m: int = 32):
    """The acceptance rows: host counting-sort baseline, PR 1 argsort
    device path, and the jitted single-pass plan, same data, same machine.
    Always full-size — these rows are the perf trajectory."""
    from repro.core.ir import _mix_hash
    from repro.data.device_repartition import (clear_plan_cache,
                                               device_rebucket,
                                               plan_cache_stats)
    import jax.numpy as jnp
    cols, keys = _shuffle_data(n, m)

    def host():
        pids = np.asarray(_mix_hash(jnp.asarray(keys))).astype(np.int64) % m
        order = np.argsort(pids, kind="stable")
        out = {k: v[order] for k, v in cols.items()}
        out["__key__"] = keys[order]
        return out, np.bincount(pids, minlength=m)

    t_host, (ref_cols, ref_counts) = _best_of(host)
    t_legacy, (leg_cols, leg_counts) = _best_of(
        lambda: _legacy_rebucket(cols, keys, m))
    clear_plan_cache()
    device_rebucket(cols, keys, m)            # trace once, outside the timer
    t_plan, (new_cols, new_counts) = _best_of(
        lambda: device_rebucket(cols, keys, m))
    stats = plan_cache_stats()

    for k in ref_cols:                        # the speedup must be bit-exact
        np.testing.assert_array_equal(ref_cols[k], leg_cols[k])
        np.testing.assert_array_equal(ref_cols[k], new_cols[k])
    speedup = t_legacy / t_plan
    emit(f"repartition_host_n{n:.0e}_m{m}".replace("e+0", "e"),
         t_host * 1e6,
         "host numpy stable-argsort re-bucket (engine host-path baseline)")
    emit(f"repartition_device_argsort_n{n:.0e}_m{m}".replace("e+0", "e"),
         t_legacy * 1e6, "PR1 path: eager argsort + per-column gather/sync")
    emit(f"repartition_device_n{n:.0e}_m{m}".replace("e+0", "e"),
         t_plan * 1e6,
         f"single-pass plan: counting-sort + packed gather "
         f"speedup_vs_argsort={speedup:.2f}x traces={stats['traces']} "
         f"plans={stats['plans']} (target >=2x)")


def d2d_repartition(n: int = 1_000_000, m: int = 32):
    """Device-to-device StoredDataset repartition vs the PR 1 route
    (host gather() + full re-write).  Always full-size."""
    from repro.data.partition_store import PartitionStore
    cols, _ = _shuffle_data(n, m, seed=1)
    wl = author_integrator()
    cand = enumerate_candidates(wl.graph, "submissions")[0]

    store = PartitionStore(m, backend="device")
    ds = store.write("submissions", cols)              # round-robin layout

    def via_host():                                    # PR 1 repartition
        flat = ds.gather()
        return store.write("h_reparted", flat, cand)

    def via_d2d():
        new, _ = store.repartition(ds, cand, name="d_reparted")
        return new

    t_host, ds_h = _best_of(via_host, repeats=2)
    via_d2d()                                          # trace once
    t_d2d, ds_d = _best_of(via_d2d, repeats=2)
    np.testing.assert_array_equal(ds_h.counts, ds_d.counts)
    fh, fd = ds_h.gather(), ds_d.gather()
    for k in fh:
        np.testing.assert_array_equal(fh[k], fd[k])
    emit(f"repartition_d2d_n{n:.0e}_m{m}".replace("e+0", "e"),
         t_d2d * 1e6,
         f"device→device, no host gather; gather+rewrite={t_host * 1e6:.0f}us "
         f"speedup={t_host / t_d2d:.2f}x path={store.write_log[-1].get('path')}"
         f" (CPU host<->device copies are zero-copy; the elided gather is a"
         f" real transfer on TPU)")


# -- skew-adaptive capacity (DESIGN §12): zipf keys, split/merge layouts ----

def device_repartition_skew(n: int = 1_000_000, m: int = 32):
    """Skew rows: the same d2d repartition over Zipf-keyed data, with and
    without the capacity map, against a balanced-key baseline.  The map
    must hold padded bytes near the uniform baseline (≤1.3×) where the
    plain uniform-capacity layout blows up (≥2×), without retracing the
    scatter plan per skew level (offsets are a traced argument)."""
    from repro.data.device_repartition import plan_cache_stats
    from .common import zipf_keys
    n = scale(n, 120_000)
    wl = author_integrator()
    cand = enumerate_candidates(wl.graph, "submissions")[0]

    def reparted(alpha, adaptive):
        cols, _ = _shuffle_data(n, m, seed=2)
        if alpha is not None:
            cols["author"] = zipf_keys(n, n, alpha,
                                       rng=np.random.default_rng(11))
        store = PartitionStore(m, backend="device",
                               adaptive_capacity=adaptive)
        ds = store.write("submissions", cols)       # round-robin layout

        def go():
            new, _ = store.repartition(ds, cand, name="reparted")
            return new

        go()                                        # trace once
        t, out = _best_of(go, repeats=2)
        return t, out

    t_uni, ds_uni = reparted(None, True)    # balanced ⇒ map planner says no
    t_cm, ds_cm = reparted(1.1, True)
    t_plain, ds_plain = reparted(1.1, False)

    assert ds_uni.capacity_map is None
    assert ds_cm.capacity_map is not None
    fc, fp = ds_cm.gather(), ds_plain.gather()      # bit-identical layouts
    for k in fc:
        np.testing.assert_array_equal(fc[k], fp[k])

    pu, pc, pp = (float(d.padded_bytes) for d in (ds_uni, ds_cm, ds_plain))
    # power-of-two buckets guarantee padded < 2× valid whatever the skew;
    # in practice the map stays near the balanced-key baseline while the
    # uniform-capacity layout scales with the hottest partition
    assert pc < 2.0 * float(ds_cm.valid_bytes), (pc, ds_cm.valid_bytes)
    assert pc <= 1.5 * pu, (pc, pu)                 # map holds the padding
    assert pp >= 2.0 * pu, (pp, pu)                 # without it, skew pays

    # no-retrace bound: further skew levels hit the same traced plans
    before = plan_cache_stats()["traces"]
    for alpha in (1.05, 1.2, 1.5):
        reparted(alpha, True)
    traces = plan_cache_stats()["traces"]
    assert traces == before, (traces, before)

    suffix = f"n{n:.0e}_m{m}".replace("e+0", "e")
    emit(f"repartition_unikey_{suffix}", t_uni * 1e6,
         f"balanced keys, uniform capacity: padded_bytes={int(pu)} "
         f"skew={ds_uni.skew():.2f}")
    emit(f"repartition_zipf_{suffix}", t_cm * 1e6,
         f"zipf(1.1) keys, capacity map: padded_bytes={int(pc)} "
         f"valid_bytes={int(ds_cm.valid_bytes)} "
         f"padded_vs_uniform={pc / pu:.2f}x (bound <2x valid) "
         f"skew={ds_cm.skew():.2f} "
         f"buckets={len(ds_cm.capacity_map.bucket_set())} "
         f"vs_unikey={t_cm / t_uni:.2f}x traces_flat={traces}=={before}")
    emit(f"repartition_zipf_nocmap_{suffix}", t_plain * 1e6,
         f"zipf(1.1) keys, uniform capacity: padded_bytes={int(pp)} "
         f"padding_waste={int(ds_plain.padding_waste())} "
         f"padded_vs_uniform={pp / pu:.2f}x (>=2x — what the map removes) "
         f"vs_unikey={t_plain / t_uni:.2f}x")


# -- planner/executor split (ISSUE 4): plan compile vs exec, cached re-runs --

def plan_compile_vs_exec(workers: int = 8):
    """Session planning cost vs execution cost, plus cached-plan re-run
    rows: a plan-cache hit must show ~zero planning cost and a flat
    ShufflePlan trace counter across repeated ``session.run``."""
    from repro.api import Session
    from repro.data.device_repartition import plan_cache_stats
    from .bench_reddit import make_data

    subs, auths = make_data(scale(100_000, 5_000), scale(25_000, 1_200))
    wl = author_integrator()
    for backend in ("host", "device"):
        store = PartitionStore(workers)
        store.write("submissions", subs)       # rr ⇒ both shuffles real
        store.write("authors", auths)
        sess = Session(store, backend=backend)

        t0 = time.perf_counter()
        sess.plan(wl)                          # cold: logical + compile
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        sess.plan(wl)                          # warm: pure cache hit
        t_hit = time.perf_counter() - t0

        sess.run(wl)                           # traces the device plans once
        base_traces = sess.plan_cache_stats()["traces"]
        best_exec, planning = float("inf"), float("inf")
        for _ in range(3):                     # cached re-runs
            t0 = time.perf_counter()
            res = sess.run(wl)
            best_exec = min(best_exec, time.perf_counter() - t0)
            planning = min(planning, res.stats.planning_s)
            assert res.stats.plan_cache_hit
        stats = sess.plan_cache_stats()
        # the no-retrace guarantee: repeated runs of an unchanged workload
        # on an unchanged layout generation never re-trace
        assert stats["traces"] == base_traces, (stats, base_traces)
        if backend == "host":
            emit("plan_compile_vs_exec", t_compile * 1e6,
                 f"exec={best_exec * 1e6:.0f}us hit={t_hit * 1e6:.1f}us "
                 f"compile/exec={t_compile / best_exec:.3f} "
                 f"hits={stats['hits']} misses={stats['misses']}")
        emit(f"plan_cached_rerun_{backend}", best_exec * 1e6,
             f"planning={planning * 1e6:.1f}us (cache hit) "
             f"traces_flat={stats['traces']}=={base_traces} "
             f"plan_cache={stats['hits']}h/{stats['misses']}m "
             f"dev_plan_stats={plan_cache_stats()['plans']}plans")


# -- observability overhead (DESIGN §13): tracing off/sampled/full rows -----

def tracing_overhead(workers: int = 8):
    """The §13 overhead contract: with tracing **off**, the plan-cache-hit
    run path must stay within 2%.  The assert is deterministic — spans a
    hit-run would record × the measured per-disabled-span-call cost,
    against the measured hit wall — instead of differencing two noisy
    end-to-end walls (whose jitter dwarfs a nanosecond-scale guard)."""
    from repro import obs
    from repro.api import Session
    from .bench_reddit import make_data

    subs, auths = make_data(scale(100_000, 5_000), scale(25_000, 1_200))
    store = PartitionStore(workers)
    store.write("submissions", subs)
    store.write("authors", auths)
    sess = Session(store)
    wl = author_integrator()
    sess.run(wl)                                   # compile + trace once

    def best_run(repeats=5):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = sess.run(wl)
            best = min(best, time.perf_counter() - t0)
            assert res.stats.plan_cache_hit
        return best

    obs.disable()
    t_off = best_run()
    # disabled-span unit cost: one module-global load + the shared no-op
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("bench.noop"):
            pass
    per_call = (time.perf_counter() - t0) / n
    # spans a single cache-hit run records (the sites the off-path pays)
    obs.enable("full")
    obs.clear_spans()
    sess.run(wl)
    spans_per_run = len(obs.finished_spans())
    t_full = best_run()
    obs.configure(mode="sampled", sample_every=16)
    t_sampled = best_run()
    obs.disable()
    obs.clear_spans()

    modeled = spans_per_run * per_call
    budget = 0.02 * t_off
    assert modeled < budget, (
        f"tracing-off overhead blew the 2% budget: {spans_per_run} spans x "
        f"{per_call * 1e9:.0f}ns = {modeled * 1e6:.2f}us vs budget "
        f"{budget * 1e6:.2f}us (hit wall {t_off * 1e6:.0f}us)")
    emit("tracing_off_cache_hit", t_off * 1e6,
         f"spans/run={spans_per_run} "
         f"per_disabled_span={per_call * 1e9:.0f}ns "
         f"modeled_overhead={modeled / t_off * 100:.3f}% (budget 2%)")
    emit("tracing_sampled_cache_hit", t_sampled * 1e6,
         f"sample_every=16 vs_off={t_sampled / t_off:.2f}x")
    emit("tracing_full_cache_hit", t_full * 1e6,
         f"vs_off={t_full / t_off:.2f}x spans/run={spans_per_run}")


# -- telemetry overhead (DESIGN §15): durable RunProfile append cost --------

def telemetry_overhead(workers: int = 8):
    """The §15 overhead contract: recording one RunProfile per run into
    the durable telemetry history must stay within the same 2% cache-hit
    budget tracing gets.  Deterministic like the §13 assert — measured
    per-append cost × the one append a run performs, against the
    measured hit wall — not a diff of two noisy end-to-end walls."""
    import tempfile

    from repro.api import Session
    from repro.obs.telemetry import RunProfile
    from .bench_reddit import make_data

    subs, auths = make_data(scale(100_000, 5_000), scale(25_000, 1_200))
    wl = author_integrator()
    with tempfile.TemporaryDirectory() as root:
        sess = Session(store_path=root, num_workers=workers)
        sess.store.write("submissions", subs)
        sess.store.write("authors", auths)
        sess.run(wl)                               # compile + trace once

        best = float("inf")
        for _ in range(5):                         # durable-store hit wall
            t0 = time.perf_counter()
            res = sess.run(wl)
            best = min(best, time.perf_counter() - t0)
            assert res.stats.plan_cache_hit

        # per-append unit cost on the same (warm) store handle
        tele = sess.telemetry_store
        profile = RunProfile(t=0.0, workload="bench", process="bench",
                             wall_s=best)
        n = 2_000
        t0 = time.perf_counter()
        for _ in range(n):
            tele.record_run(profile)
        per_record = (time.perf_counter() - t0) / n

        modeled = per_record                       # one append per run
        budget = 0.02 * best
        assert modeled < budget, (
            f"telemetry_record blew the 2% budget: {per_record * 1e6:.2f}us "
            f"per append vs budget {budget * 1e6:.2f}us "
            f"(hit wall {best * 1e6:.0f}us)")
        stats = tele.stats()
        emit("telemetry_record", per_record * 1e6,
             f"modeled_overhead={modeled / best * 100:.3f}% (budget 2%) "
             f"hit_wall={best * 1e6:.0f}us appends={stats['appends']} "
             f"compactions={stats['compactions']} (bounded history)")


def main():
    offline_overheads()
    online_consumer_matching()
    repartition_backends()
    device_repartition_scaling()
    d2d_repartition()
    device_repartition_skew()
    plan_compile_vs_exec()
    tracing_overhead()
    telemetry_overhead()


if __name__ == "__main__":
    main()
