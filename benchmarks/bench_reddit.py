"""Fig. 5 — Reddit data-integration workflow + Tab. 3 producer overhead.

Three workloads: load submissions, load authors, join on author.  With
Lachesis both loads are automatically hash-partitioned on the author key
extracted from the consumer's IR; the join then runs shuffle-free.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import author_integrator, enumerate_candidates
from repro.core.dsl import reddit_loader
from repro.data.partition_store import PartitionStore

from .common import advisor_decide, emit, run_consumer


def make_data(n_sub, n_auth, seed=0):
    rng = np.random.default_rng(seed)
    subs = {"author": rng.integers(0, n_auth, n_sub).astype(np.int64),
            "score": rng.normal(size=n_sub).astype(np.float32),
            "ups": rng.integers(0, 1000, n_sub).astype(np.int32)}
    auths = {"author": rng.permutation(n_auth).astype(np.int64),
             "karma": rng.normal(size=n_auth).astype(np.float32)}
    return subs, auths


def run_case(name, n_sub, n_auth, workers=8):
    wl = author_integrator()
    subs, auths = make_data(n_sub, n_auth)
    sub_bytes = sum(v.nbytes for v in subs.values())

    sub_cand = enumerate_candidates(wl.graph, "submissions")[0]
    auth_cand = enumerate_candidates(wl.graph, "authors")[0]

    # Alg. 3: the advisor must pick the keyed candidate from history
    loader = reddit_loader("submission-loader", "raw_subs", "submissions",
                           "json")
    decision = advisor_decide(loader, "submissions", wl, sub_cand.signature(),
                              dataset_bytes=sub_bytes)
    assert decision.candidate.is_keyed, "advisor failed to pick keyed"

    # w/o Lachesis: round-robin storage (paper baseline)
    store = PartitionStore(workers)
    t0 = time.perf_counter()
    store.write("submissions", subs)
    store.write("authors", auths)
    producer_rr = time.perf_counter() - t0
    base = run_consumer(store, wl)

    # w/ Lachesis: advisor-selected persistent partitioning at storage time
    store2 = PartitionStore(workers)
    t0 = time.perf_counter()
    store2.write("submissions", subs, decision.candidate)
    store2.write("authors", auths, auth_cand)
    producer_part = time.perf_counter() - t0
    opt = run_consumer(store2, wl)

    speedup_wall = base["wall_s"] / opt["wall_s"]
    speedup_model = base["modeled_s"] / opt["modeled_s"]
    overhead = producer_part / max(producer_rr, 1e-9) - 1.0
    emit(f"reddit_{name}_consumer", opt["wall_s"] * 1e6,
         f"speedup_wall={speedup_wall:.2f}x "
         f"speedup_modeled={speedup_model:.2f}x "
         f"shuffles {base['shuffles']}->{opt['shuffles']} "
         f"elided={opt['elided']} bytes_saved={base['shuffle_bytes']}")
    emit(f"reddit_{name}_producer", producer_part * 1e6,
         f"partition_overhead={overhead * 100:.0f}% (paper Tab.3: <=10%)")
    return speedup_wall, speedup_model


def main():
    run_case("small", 200_000, 50_000)
    run_case("large", 1_200_000, 300_000)


if __name__ == "__main__":
    main()
