"""Durable storage tier benchmarks (DESIGN §10).

Four rows per scale:

* ``storage_flush`` — persisting one generation (segment writes + manifest
  publish), the durability tax each autoflushed write pays;
* ``storage_cold_open`` — a FRESH process attaching to the store and doing
  its first full scan: manifest load + zero-copy memmap + page-in;
* ``storage_warm_scan`` — the same scan once the page cache is hot, the
  steady-state read path a reopened application actually sees;
* ``storage_spill_rerun`` — scans under a memory budget that forces the
  eviction loop to spill between reads, i.e. the cost of a dataset that
  does not fit in RAM.

Plus the headline ``storage_reopen_elide`` row: a second Session on the
same store runs the consumer workload against the layout the first session
paid for — shuffle count and bytes must be zero (paper §1: layouts reused
"across applications").
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.api import Session
from repro.core import Workload, enumerate_candidates
from repro.data.partition_store import PartitionStore

from .common import emit, scale


def _dataset(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, max(n // 16, 4), size=n).astype(np.int64),
            "a": rng.standard_normal(n).astype(np.float32),
            "b": rng.integers(0, 1 << 30, size=n).astype(np.int32)}


def _keyed(dataset="events"):
    wl = Workload("w")
    t = wl.scan(dataset)
    wl.partition(t["k"])
    return enumerate_candidates(wl.graph, dataset)[0]


def _consumer():
    wl = Workload("storage-consumer")
    t = wl.scan("events")
    p = wl.partition(t["k"])
    wl.aggregate(p, reducer="sum")
    return wl


def _time(fn, repeats=3):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_persistence(n: int, m: int = 8) -> None:
    data = _dataset(n)
    nbytes = sum(v.nbytes for v in data.values())
    root = tempfile.mkdtemp(prefix="lachesis-bench-store-")
    try:
        store = PartitionStore(num_workers=m, root=root, autoflush=False)
        store.write("events", data, _keyed())

        t_flush, _ = _time(lambda: (store._dirty.add("events"),
                                    store.flush("events"))[-1])
        emit(f"storage_flush_n{n}_m{m}", t_flush * 1e6,
             f"bytes={nbytes} GBps={nbytes / t_flush / 1e9:.2f}")

        def cold_open():
            s = PartitionStore.open(root)       # fresh attach: manifests only
            return s.read("events").gather()["a"].sum()
        t_cold, _ = _time(cold_open, repeats=1)
        emit(f"storage_cold_open_n{n}_m{m}", t_cold * 1e6,
             f"bytes={nbytes} GBps={nbytes / t_cold / 1e9:.2f}")

        warm = PartitionStore.open(root)
        warm.read("events").gather()            # fault every page in
        t_warm, _ = _time(
            lambda: warm.read("events").gather()["a"].sum())
        emit(f"storage_warm_scan_n{n}_m{m}", t_warm * 1e6,
             f"bytes={nbytes} GBps={nbytes / t_warm / 1e9:.2f}")

        # spill pressure: budget below one dataset ⇒ every write re-spills,
        # every scan reads through disk-backed views
        tight = PartitionStore(num_workers=m, root=root + "-tight",
                               memory_budget_bytes=nbytes // 2)
        tight.write("events", data, _keyed())
        assert tight.is_spilled("events")
        t_spill, _ = _time(
            lambda: tight.read("events").gather()["a"].sum())
        io = tight.io_snapshot()
        emit(f"storage_spill_rerun_n{n}_m{m}", t_spill * 1e6,
             f"bytes={nbytes} spills={int(io['spills'])} "
             f"vs_warm={t_spill / max(t_warm, 1e-9):.2f}x")
        shutil.rmtree(root + "-tight", ignore_errors=True)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_reopen_elide(n: int, m: int = 8) -> None:
    """Process-A-pays, process-B-rides: the second Session's consumer run
    must perform zero shuffles against the persisted layout."""
    root = tempfile.mkdtemp(prefix="lachesis-bench-reuse-")
    try:
        a = Session(store_path=root, num_workers=m)
        data = _dataset(n)
        del data["b"]            # keyed agg over int32 sums would overflow
        a.write("events", data, _keyed())
        res_a = a.run(_consumer())
        assert res_a.stats.shuffles_elided == 1

        def reopen_run():
            b = Session(store_path=root)
            return b.run(_consumer())
        t_b, res_b = _time(reopen_run, repeats=2)
        assert res_b.stats.shuffles_performed == 0
        assert res_b.stats.shuffle_bytes == 0
        emit(f"storage_reopen_elide_n{n}_m{m}", t_b * 1e6,
             f"elided={res_b.stats.shuffles_elided} shuffle_bytes=0 "
             f"cold_session_wall_s={t_b:.4f}")
    finally:
        shutil.rmtree(root, ignore_errors=True)


def main() -> None:
    n = scale(1_000_000, 100_000)
    bench_persistence(n)
    bench_reopen_elide(scale(300_000, 50_000))


if __name__ == "__main__":
    main()
