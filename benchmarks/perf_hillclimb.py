import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver — hypothesis → change → re-lower → measure.

Three assigned cells (worst roofline fraction / most collective-bound /
most paper-representative) + one bonus MLA-decode climb.  Each variant is a
config or sharding-spec change; the measurement is the re-derived roofline
terms from the recompiled artifact.  Results → results/hillclimb.json.
"""

import json
import time
import traceback

from repro.launch.dryrun import analyze_cell

# (cell, multi_pod, [(variant_name, hypothesis, extra_cfg, variant), ...])
CLIMBS = [
    # 1. most representative of the paper's technique: MoE+MLA training —
    #    the expert dispatch IS Lachesis-style partitioning/shuffle
    ("deepseek-v2-236b", "train_4k", False, [
        ("baseline", "paper-faithful defaults (remat=full, accum=4)",
         {}, {}),
        ("remat_dots",
         "save matmul outputs in remat: bwd recompute drops from ~fwd to "
         "elementwise-only ⇒ compute term −~25%, memory term −~20%",
         {"remat_policy": "dots"}, {}),
        ("remat_dots_accum8",
         "8 microbatches halve live activations again; MoE dispatch buffers "
         "shrink 2x; expect temp ↓ ~2x, collective ↑ (2x more weight "
         "gathers)", {"remat_policy": "dots", "accum_steps": 8}, {}),
    ]),
    # 2. most collective-bound: llama4 train on the multi-pod mesh
    ("llama4-maverick-400b-a17b", "train_4k", True, [
        ("baseline", "accum=4 ⇒ FSDP weight all-gathers run 4x per step",
         {}, {}),
        ("accum2",
         "halving microbatches halves FSDP re-gathers ⇒ collective −~2x, "
         "temp ↑ ~2x (activations)", {"accum_steps": 2}, {}),
        ("accum2_dots",
         "remat-dots on top: compute −25%, memory −; collective unchanged",
         {"accum_steps": 2, "remat_policy": "dots"}, {}),
    ]),
    # 3. worst roofline fraction: qwen decode (0.26% of memory roofline;
    #    SPMD 'involuntary full remat' warnings = cache replication)
    ("qwen1.5-110b", "decode_32k", False, [
        ("baseline", "head/hd-sharded KV cache; XLA replicates cache to "
         "reshard q/k transposes (the warning) ⇒ memory 2.76s", {}, {}),
        ("cache_seq_shard",
         "shard cache SEQUENCE over model (flash-decode): per-device cache "
         "reads /16, resharding transposes disappear ⇒ memory −~10x, small "
         "psum for softmax combine", {}, {"cache_seq_shard": True}),
        ("seqshard_fsdp",
         "weights over dp too: per-device weight reads 13.9GB→0.87GB, but "
         "if XLA all-gathers them the wire cost (13.9GB/50GBps=278ms) "
         "dominates — hypothesis: collective ↑ beyond the memory saving "
         "(expected REFUTATION of naive FSDP-for-decode)",
         {}, {"cache_seq_shard": True, "fsdp_params": True}),
    ]),
    # bonus: absorbed-MLA decode (beyond-paper algorithmic change)
    ("deepseek-v2-236b", "decode_32k", False, [
        ("baseline", "naive MLA decode expands K/V to (B,L,H,256) per step",
         {}, {}),
        ("absorbed_mla",
         "score in latent space: cache-side traffic per token drops from "
         "H*(nd+vd)=32768 to R+rd=576 floats ⇒ memory term −~5-20x",
         {"mla_absorbed": True}, {}),
        ("absorbed_seqshard",
         "latent cache sequence-sharded over model on top ⇒ another /16 on "
         "cache reads", {"mla_absorbed": True}, {"cache_seq_shard": True}),
    ]),
]


def main():
    out = []
    for arch, shape, multi_pod, variants in CLIMBS:
        for name, hypothesis, extra_cfg, variant in variants:
            t0 = time.time()
            try:
                rec = analyze_cell(arch, shape, multi_pod=multi_pod,
                                   extra_cfg=extra_cfg, variant=variant)
                rec["climb_variant"] = name
                rec["hypothesis"] = hypothesis
                out.append(rec)
                print(f"== {arch} × {shape} [{name}]: "
                      f"comp={rec['compute_s']*1e3:.1f}ms "
                      f"mem={rec['memory_s']*1e3:.1f}ms "
                      f"coll={rec['collective_s']*1e3:.1f}ms "
                      f"temp={rec['memory_analysis']['temp_bytes']/2**30:.1f}"
                      f"GiB ({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:
                traceback.print_exc()
                out.append({"arch": arch, "shape": shape,
                            "climb_variant": name, "error": repr(e)})
    path = os.path.join(os.path.dirname(__file__), "results",
                        "hillclimb.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
