"""Kernel microbenchmarks: oracle wall times + kernel equivalence.

On CPU the Pallas kernels run in interpret mode (Python-speed — correctness
only), so the timed path is the jnp oracle; per-shape allclose against the
kernel is asserted as part of the row.  On TPU the same harness times the
compiled kernels (`use_kernel=True`).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_partition.hash_partition import hash_partition
from repro.kernels.hash_partition.ref import hash_partition_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan

from .common import emit


def _time(fn, *args, n=5):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_flash():
    key = jax.random.PRNGKey(0)
    B, H, KV, S, hd = 1, 8, 2, 1024, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    ref = jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True))
    t = _time(ref, q, k, v)
    out_k = flash_attention(q, k, v, causal=True, block_q=256, block_k=256,
                            interpret=True)
    err = float(jnp.abs(out_k - ref(q, k, v)).max())
    flops = 4 * B * H * S * S * hd / 2
    emit("kernel_flash_attention", t * 1e6,
         f"oracle {flops / t / 1e9:.1f} GFLOP/s; kernel allclose "
         f"err={err:.1e} (interpret)")


def bench_hash_partition():
    key = jax.random.PRNGKey(1)
    n, m = 1_000_000, 256
    keys = jax.random.randint(key, (n,), 0, 2 ** 31 - 1, jnp.int32)
    ref = jax.jit(lambda x: hash_partition_ref(x, m))
    t = _time(ref, keys)
    pk, ck = hash_partition(keys[:8192], m, interpret=True)
    rk, rc = hash_partition_ref(keys[:8192], m)
    ok = bool(jnp.array_equal(pk, rk) and jnp.array_equal(ck, rc))
    emit("kernel_hash_partition", t * 1e6,
         f"oracle {n / t / 1e6:.0f} Mkeys/s over m={m}; kernel exact={ok}")


def bench_scatter_perm():
    """Counting-sort destination permutation (ISSUE 2): O(N) stable
    placement vs the O(N log N) argsort-inverse it replaces."""
    from repro.kernels.hash_partition.hash_partition import scatter_perm
    from repro.kernels.hash_partition.ref import scatter_perm_ref
    key = jax.random.PRNGKey(3)
    n, m = 1_000_000, 32
    pids = jax.random.randint(key, (n,), 0, m, jnp.int32)
    counts = jnp.bincount(pids, length=m).astype(jnp.int32)
    ref = jax.jit(scatter_perm_ref)
    t = _time(ref, pids, counts)
    dk = scatter_perm(pids[:8192],
                      jnp.bincount(pids[:8192], length=m).astype(jnp.int32),
                      interpret=True)
    dr_ = scatter_perm_ref(pids[:8192],
                           jnp.bincount(pids[:8192],
                                        length=m).astype(jnp.int32))
    ok = bool(jnp.array_equal(dk, dr_))
    emit("kernel_scatter_perm", t * 1e6,
         f"oracle (argsort-inverse) {n / t / 1e6:.0f} Mrows/s over m={m}; "
         f"counting-sort kernel exact={ok}")


def bench_ssd():
    key = jax.random.PRNGKey(2)
    B, T, H, P, N, chunk = 1, 2048, 8, 64, 128, 256
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.3
    ref = jax.jit(lambda *a: ssd_ref(*a, chunk))
    t = _time(ref, x, dt, A, Bm, Cm)
    yk, sk = ssd_scan(x[:, :256], dt[:, :256], A, Bm[:, :256], Cm[:, :256],
                      chunk, interpret=True)
    yr, sr = ssd_ref(x[:, :256], dt[:, :256], A, Bm[:, :256], Cm[:, :256],
                     chunk)
    err = float(jnp.abs(yk - yr).max())
    emit("kernel_ssd_scan", t * 1e6,
         f"oracle {B * T * H / t / 1e6:.2f} Mtok-head/s; kernel allclose "
         f"err={err:.1e} (interpret)")


def bench_device_rebucket():
    """Host numpy re-bucket vs the jax-backed re-bucket (DESIGN §5).

    The timed device path uses the jnp oracle for pids (the Pallas kernel in
    interpret mode is Python-speed on CPU); kernel-path exactness is asserted
    on a slice, so the row certifies the full device path while timing the
    representative jnp work."""
    from repro.core.ir import _mix_hash
    from repro.data.device_repartition import device_rebucket

    rng = np.random.default_rng(3)
    n, m = 500_000, 64
    cols = {"key": rng.integers(0, 2 ** 31 - 1, n).astype(np.int64),
            "val": rng.normal(size=n).astype(np.float32)}
    keys = cols["key"]

    def host():
        pids = np.asarray(_mix_hash(keys)).astype(np.int64) % m
        order = np.argsort(pids, kind="stable")
        counts = np.bincount(pids, minlength=m)
        return {k: v[order] for k, v in cols.items()}, counts

    t0 = time.perf_counter()
    host_cols, host_counts = host()
    t_host = time.perf_counter() - t0

    device_rebucket(cols, keys, m, use_kernel=False)   # trace the plan once
    t0 = time.perf_counter()
    dev_cols, dev_counts = device_rebucket(cols, keys, m, use_kernel=False)
    t_dev = time.perf_counter() - t0

    np.testing.assert_array_equal(host_counts, dev_counts)
    np.testing.assert_array_equal(host_cols["val"], dev_cols["val"])
    k_cols, k_counts = device_rebucket(
        {k: v[:8192] for k, v in cols.items()}, keys[:8192], m,
        mode="fused", use_kernel=True, interpret=True)
    ok = bool(np.array_equal(
        k_cols["val"],
        device_rebucket({k: v[:8192] for k, v in cols.items()}, keys[:8192],
                        m, use_kernel=False)[0]["val"]))
    emit("kernel_device_rebucket", t_dev * 1e6,
         f"host_numpy={t_host * 1e6:.0f}us n={n} m={m} "
         f"device/host={t_dev / t_host:.2f}x kernel_exact={ok}")


def bench_scatter_skew():
    """Variable-capacity scatter (DESIGN §12): the same fused scatter plan
    writing a Zipf-skewed padded layout through a :class:`CapacityMap`
    (flat slot ranges, power-of-two buckets) vs the uniform ``(m, cap)``
    layout sized by the hottest partition."""
    from repro.data.capacity import plan_capacity_map, valid_slot_index
    from repro.data.device_repartition import (device_partition_ids,
                                               device_scatter_padded)
    from repro.data.skew import zipf_keys

    n, m = 500_000, 32
    rng = np.random.default_rng(5)
    keys = zipf_keys(n, n, 1.1, rng=rng)
    cols = {"key": keys, "val": rng.normal(size=n).astype(np.float32)}
    pids_d, hist = device_partition_ids(keys, m, use_kernel=False)
    pids = np.asarray(pids_d).astype(np.int64)
    counts = np.asarray(hist).astype(np.int64)
    cmap = plan_capacity_map(counts)
    assert cmap is not None                       # zipf keys must bucket

    def uniform():
        return device_scatter_padded(cols, pids, counts)

    def bucketed():
        return device_scatter_padded(cols, pids, counts, capacity_map=cmap)

    uniform(); bucketed()                         # trace outside the timer
    t_uni, out_u = _time(lambda: uniform()["val"], n=3), uniform()
    t_cm, out_b = _time(lambda: bucketed()["val"], n=3), bucketed()

    cap = int(counts.max())
    uni_off = np.arange(m, dtype=np.int64) * cap
    flat_u = np.asarray(out_u["val"]).reshape(-1)[
        valid_slot_index(counts, uni_off)]
    flat_b = np.asarray(out_b["val"])[valid_slot_index(counts, cmap.offsets)]
    np.testing.assert_array_equal(flat_u, flat_b)  # bit-identical rows

    slots_u, slots_b = m * cap, cmap.total_slots
    emit("kernel_scatter_skew", t_cm * 1e6,
         f"uniform={t_uni * 1e6:.0f}us n={n} m={m} zipf(1.1) "
         f"slots {slots_b} vs {slots_u} ({slots_u / slots_b:.1f}x fewer) "
         f"buckets={len(cmap.bucket_set())} bucketed/uniform="
         f"{t_cm / t_uni:.2f}x (one shared trace)")


def main():
    bench_flash()
    bench_hash_partition()
    bench_scatter_perm()
    bench_ssd()
    bench_device_rebucket()
    bench_scatter_skew()


if __name__ == "__main__":
    main()
