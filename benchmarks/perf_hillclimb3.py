import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb — round 3: interleaved RoPE (shard-local rotation).

Code change: apply_rope now rotates adjacent pairs instead of rotate-half,
so the rotation never crosses hd shards.  Hypothesis: the 'involuntary full
rematerialization' SPMD fallbacks on the decode path disappear ⇒ qwen
decode memory AND collective both drop ~2x+.
"""

import json, time, traceback
from repro.launch.dryrun import analyze_cell

CLIMBS = [
    ("qwen1.5-110b", "decode_32k", False, [
        ("ileave_rope", "shard-local rope kills cache replication", {}, {}),
        ("ileave_rope_seqshard", "plus L-sharded cache", {},
         {"cache_seq_shard": True}),
    ]),
    ("qwen1.5-110b", "train_4k", False, [
        ("ileave_rope_train", "same fix on the train path (rope on q,k at "
         "S=4096): fewer reshard copies", {}, {}),
    ]),
    ("llama4-maverick-400b-a17b", "decode_32k", False, [
        ("ileave_rope", "llama4 decode was collective-bound (3.08s) via the "
         "same replication", {}, {}),
    ]),
]

out = []
for arch, shape, multi_pod, variants in CLIMBS:
    for name, hypothesis, extra_cfg, variant in variants:
        t0 = time.time()
        try:
            rec = analyze_cell(arch, shape, multi_pod=multi_pod,
                               extra_cfg=extra_cfg, variant=variant)
            rec["climb_variant"] = name
            rec["hypothesis"] = hypothesis
            out.append(rec)
            print(f"== {arch} × {shape} [{name}]: "
                  f"comp={rec['compute_s']*1e3:.1f}ms "
                  f"mem={rec['memory_s']*1e3:.1f}ms "
                  f"coll={rec['collective_s']*1e3:.1f}ms "
                  f"temp={rec['memory_analysis']['temp_bytes']/2**30:.1f}GiB "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            traceback.print_exc()
            out.append({"arch": arch, "shape": shape,
                        "climb_variant": name, "error": repr(e)})
with open(os.path.join(os.path.dirname(__file__), "results",
                       "hillclimb3.json"), "w") as f:
    json.dump(out, f, indent=1)
print("wrote hillclimb3.json")
