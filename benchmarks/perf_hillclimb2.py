import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb — round 2 (after the round-1 lessons).

Code change since round 1: sdpa now einsums on the native (B,S,KV,hd)
layout with f32 accumulation — no transposed/upcast K-V copies.
Round-2 hypotheses below; results → results/hillclimb2.json.
"""

import json
import time
import traceback

from repro.launch.dryrun import analyze_cell

CLIMBS = [
    ("qwen1.5-110b", "decode_32k", False, [
        ("native_sdpa",
         "no f32/transposed cache copies ⇒ memory −~2x vs round-1 baseline "
         "(2762ms)", {}, {}),
        ("native_seqshard",
         "plus L-sharded cache: round-1 showed seq-shard kills the "
         "replication collectives (2205→368ms); with copies gone memory "
         "should now DROP too", {}, {"cache_seq_shard": True}),
    ]),
    ("deepseek-v2-236b", "train_4k", False, [
        ("accum8_nodots",
         "8 microbatches at full remat: dispatch buffers + residual set "
         "halve ⇒ memory −~25%, collective +~15% (2x weight regathers)",
         {"accum_steps": 8}, {}),
    ]),
    ("llama4-maverick-400b-a17b", "train_4k", True, [
        ("accum1",
         "single macrobatch: FSDP weight gathers once per step ⇒ "
         "collective −~2x vs accum2 (24.9s), temp ×~2",
         {"accum_steps": 1}, {}),
    ]),
    ("deepseek-v2-236b", "decode_32k", False, [
        ("absorbed_seqshard_native",
         "round-1 best (1049ms mem / 1248ms coll) + native sdpa on the "
         "rope-score path ⇒ both terms −", {"mla_absorbed": True},
         {"cache_seq_shard": True}),
    ]),
]


def main():
    out = []
    for arch, shape, multi_pod, variants in CLIMBS:
        for name, hypothesis, extra_cfg, variant in variants:
            t0 = time.time()
            try:
                rec = analyze_cell(arch, shape, multi_pod=multi_pod,
                                   extra_cfg=extra_cfg, variant=variant)
                rec["climb_variant"] = name
                rec["hypothesis"] = hypothesis
                out.append(rec)
                print(f"== {arch} × {shape} [{name}]: "
                      f"comp={rec['compute_s']*1e3:.1f}ms "
                      f"mem={rec['memory_s']*1e3:.1f}ms "
                      f"coll={rec['collective_s']*1e3:.1f}ms "
                      f"temp={rec['memory_analysis']['temp_bytes']/2**30:.1f}"
                      f"GiB ({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:
                traceback.print_exc()
                out.append({"arch": arch, "shape": shape,
                            "climb_variant": name, "error": repr(e)})
    path = os.path.join(os.path.dirname(__file__), "results",
                        "hillclimb2.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
