"""Shared helpers for the benchmark suite (paper §5 reproduction).

Every benchmark compares *w/ Lachesis* (inputs persistently partitioned by
the advisor's decision at storage time) vs *w/o Lachesis* (round-robin, the
paper's baseline dispatch).  Reported latency is host wall-clock of the
consumer workload; ``modeled_total`` additionally charges measured shuffle
bytes at the paper's 10 Gbps cluster bandwidth — on this single host the
wall-clock difference already reflects the re-bucketing work, the modeled
number maps it onto the paper's cluster setting.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.api import Session
from repro.core import HistoryStore, partitioning_creation
from repro.core.advisor import GreedySelector
from repro.data.partition_store import PartitionStore
from repro.data.skew import zipf_keys  # noqa: F401 — canonical skewed-key
                                       # generator, shared with drivers.py

NET_BW = 1.25e9      # 10 Gbps

# `scripts/verify.sh --bench` sets LACHESIS_BENCH_SMOKE=1: suites shrink
# their synthetic inputs so the whole run is a CI-sized smoke pass.  The
# headline device-repartition rows keep their full N (they are seconds-scale
# and the perf trajectory is diffed on them across BENCH_*.json snapshots).
SMOKE = os.environ.get("LACHESIS_BENCH_SMOKE", "") not in ("", "0")


def scale(n: int, smoke_n: int) -> int:
    """Full size normally, `smoke_n` under LACHESIS_BENCH_SMOKE=1."""
    return min(n, smoke_n) if SMOKE else n


def run_consumer(store: PartitionStore, workload, repeats: int = 3,
                 backend: str = "host"):
    sess = Session(store, backend=backend)
    best = None
    match_s = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        _vals, stats = sess.run(workload)
        wall = time.perf_counter() - t0
        # Alg. 4 runs at plan time, so only the compiling (cache-miss) run
        # carries it; cache hits report 0 — keep the real matching cost
        match_s = max(match_s, stats.match_overhead_s)
        if best is None or wall < best[0]:
            best = (wall, stats)
    wall, stats = best
    modeled = wall + stats.modeled_network_s(NET_BW)
    return {"wall_s": wall, "modeled_s": modeled,
            "shuffle_bytes": stats.shuffle_bytes,
            "shuffles": stats.shuffles_performed,
            "elided": stats.shuffles_elided,
            "device_repartitions": stats.device_repartitions,
            "match_overhead_s": match_s}


def advisor_decide(producer, dataset, consumer, cand_sig, *,
                   dataset_bytes, n_history=3):
    """Build history (producer→consumer lineage) and run Alg. 3."""
    hist = HistoryStore()
    for t in range(n_history):
        hist.log_workload(producer, timestamp=100.0 * t, latency=30.0,
                          input_bytes=dataset_bytes)
        hist.log_workload(consumer, timestamp=100.0 * t + 50, latency=90.0,
                          input_bytes=dataset_bytes,
                          candidate_stats={cand_sig: {
                              "selectivity": 0.1, "distinct_keys": 1e6,
                              "num_objects": 1e6}})
    return partitioning_creation(producer, dataset, hist,
                                 selector=GreedySelector(),
                                 dataset_bytes=dataset_bytes)


# Rows emitted so far — run.py dumps this for --json snapshots.
ROWS: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
