import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb — round 4: jnp flash-decode (online softmax over key
blocks; scores never materialize at full cache length).

Hypothesis: qwen decode memory term is dominated by (B,32k,KV,G) f32
scores/probs traffic (~537MB × 80 layers × several softmax-chain passes,
per-op decomposition in EXPERIMENTS §Perf).  flash-decode caps live scores
at (B,block,KV,G) ⇒ memory −~5x; collective: the replicated-scores copies
die too.
"""

import json, time, traceback
from repro.launch.dryrun import analyze_cell

CLIMBS = [
    ("qwen1.5-110b", "decode_32k", False, [
        ("flash_decode", "scores traffic collapses; memory 2.76s -> <1s",
         {}, {}),
        ("flash_decode_seqshard", "plus L-sharded cache: reads /16",
         {}, {"cache_seq_shard": True}),
    ]),
    ("llama4-maverick-400b-a17b", "decode_32k", False, [
        ("flash_decode", "collective-bound decode (3.08s): replicated "
         "scores copies die", {}, {}),
    ]),
    ("gemma2-27b", "long_500k", False, [
        ("flash_decode", "500k global-layer cache walks in blocks", {}, {}),
    ]),
]

out = []
for arch, shape, multi_pod, variants in CLIMBS:
    for name, hypothesis, extra_cfg, variant in variants:
        t0 = time.time()
        try:
            rec = analyze_cell(arch, shape, multi_pod=multi_pod,
                               extra_cfg=extra_cfg, variant=variant)
            rec["climb_variant"] = name; rec["hypothesis"] = hypothesis
            out.append(rec)
            print(f"== {arch} × {shape} [{name}]: "
                  f"comp={rec['compute_s']*1e3:.1f}ms "
                  f"mem={rec['memory_s']*1e3:.1f}ms "
                  f"coll={rec['collective_s']*1e3:.1f}ms "
                  f"args={rec['memory_analysis']['argument_bytes']/2**30:.1f}GiB "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            traceback.print_exc()
            out.append({"arch": arch, "shape": shape,
                        "climb_variant": name, "error": repr(e)})
with open(os.path.join(os.path.dirname(__file__), "results",
                       "hillclimb4.json"), "w") as f:
    json.dump(out, f, indent=1)
print("wrote hillclimb4.json")
